//! Observability battery: histogram error bounds vs the exact
//! reservoir-style oracle, merge algebra, the lock-free span ring
//! under concurrency, and end-to-end Chrome trace validity through a
//! traced server.

use std::sync::Arc;

use bayesian_bits::engine::serve::{closed_loop, percentile};
use bayesian_bits::engine::trace::TraceEvent;
use bayesian_bits::engine::{synthetic_plan, Engine, Histogram,
                            ServeConfig, Server, SpanKind,
                            TraceRecorder};
use bayesian_bits::rng::Pcg64;
use bayesian_bits::util::json::Json;

// ------------------------------------------------------------------
// Histogram properties
// ------------------------------------------------------------------

/// The documented bound: bucket midpoints sit within 1/128 (< 1%) of
/// any value in their bucket, values below 64 are exact, and the
/// sub-64-width buckets add at most 1 of absolute rounding.
fn error_bound(exact: u64) -> f64 {
    exact as f64 / 128.0 + 1.0
}

/// Randomized value streams with qualitatively different shapes —
/// uniform-small (the exact region), uniform-wide (spans many
/// octaves), log-uniform (heavy tail), and a tight cluster.
fn distributions(seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Pcg64::new(seed);
    let n = 5000;
    let uniform_small: Vec<u64> =
        (0..n).map(|_| rng.next_below(100)).collect();
    let uniform_wide: Vec<u64> =
        (0..n).map(|_| rng.next_below(10_000_000_000)).collect();
    let log_uniform: Vec<u64> = (0..n)
        .map(|_| (1u64 << rng.next_below(50)) + rng.next_below(1000))
        .collect();
    let clustered: Vec<u64> =
        (0..n).map(|_| 1_000_000 + rng.next_below(1000)).collect();
    vec![uniform_small, uniform_wide, log_uniform, clustered]
}

#[test]
fn histogram_percentiles_within_bound_of_exact_oracle() {
    for (di, data) in distributions(41).into_iter().enumerate() {
        let mut h = Histogram::default();
        for &v in &data {
            h.record(v);
        }
        let mut sorted = data.clone();
        sorted.sort_unstable();
        assert_eq!(h.count(), data.len() as u64, "dist {di}");
        assert_eq!(h.max(), *sorted.last().unwrap(), "dist {di}");
        for q in [0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let exact = percentile(&sorted, q);
            let got = h.percentile(q);
            let err = (got as f64 - exact as f64).abs();
            assert!(
                err <= error_bound(exact),
                "dist {di} q={q}: hist {got} vs exact {exact} \
                 (err {err}, bound {})",
                error_bound(exact)
            );
        }
        // mean is exact (sum and count are not bucketed)
        let want_mean =
            sorted.iter().map(|&v| v as f64).sum::<f64>()
                / sorted.len() as f64;
        assert!((h.mean() - want_mean).abs() < 1e-6, "dist {di}");
    }
}

#[test]
fn histogram_merge_is_associative_and_order_free() {
    let parts = distributions(97);
    let hists: Vec<Histogram> = parts
        .iter()
        .map(|data| {
            let mut h = Histogram::default();
            for &v in data {
                h.record(v);
            }
            h
        })
        .collect();
    let [a, b, c, d] = &hists[..] else { unreachable!() };
    // (a + b) + c == a + (b + c), exactly (derived PartialEq)
    let mut left = a.clone();
    left.merge(b);
    left.merge(c);
    let mut right_inner = b.clone();
    right_inner.merge(c);
    let mut right = a.clone();
    right.merge(&right_inner);
    assert_eq!(left, right);
    // merge of per-worker histograms == one histogram over the
    // concatenated stream (exact bucket counts, not resampling)
    let mut merged = a.clone();
    for h in [b, c, d] {
        merged.merge(h);
    }
    let mut whole = Histogram::default();
    for data in &parts {
        for &v in data {
            whole.record(v);
        }
    }
    assert_eq!(merged, whole);
    // merging an empty histogram is the identity
    let mut with_empty = merged.clone();
    with_empty.merge(&Histogram::default());
    assert_eq!(with_empty, merged);
}

// ------------------------------------------------------------------
// Span ring buffer
// ------------------------------------------------------------------

#[test]
fn ring_survives_concurrent_recording_without_loss() {
    let rec = TraceRecorder::with_capacity(8192);
    let threads = 4usize;
    let per = 1000u64;
    std::thread::scope(|s| {
        for t in 0..threads {
            let rec = rec.clone();
            s.spawn(move || {
                for i in 0..per {
                    rec.record(SpanKind::Infer,
                               (t as u64) * 1_000_000 + i, 10,
                               t as u64, i, 0);
                }
            });
        }
    });
    let events = rec.events();
    assert_eq!(events.len(), threads * per as usize);
    assert_eq!(rec.dropped(), 0);
    for t in 0..threads as u64 {
        let mine: Vec<&TraceEvent> =
            events.iter().filter(|e| e.tid == t).collect();
        assert_eq!(mine.len(), per as usize, "tid {t}");
        // per-thread payloads all arrived intact (no torn slots)
        let mut ids: Vec<u64> = mine.iter().map(|e| e.a).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..per).collect::<Vec<u64>>(), "tid {t}");
    }
}

#[test]
fn ring_wrap_keeps_capacity_and_counts_drops() {
    let rec = TraceRecorder::with_capacity(64);
    assert_eq!(rec.capacity(), 64);
    for i in 0..200u64 {
        rec.record(SpanKind::Enqueue, i, 1, 0, i, 0);
    }
    let events = rec.events();
    assert_eq!(events.len(), 64);
    assert_eq!(rec.dropped(), 200 - 64);
    // the survivors are the newest claims
    assert!(events.iter().all(|e| e.a >= 200 - 64));
}

#[test]
fn request_ids_are_unique_across_threads() {
    let rec = TraceRecorder::new();
    let mut ids: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let rec = rec.clone();
                s.spawn(move || {
                    (0..250)
                        .map(|_| rec.next_request_id())
                        .collect::<Vec<u64>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 1000);
    assert!(*ids.first().unwrap() >= 1); // 0 means "untraced"
}

// ------------------------------------------------------------------
// End-to-end: traced server -> Chrome trace-event JSON
// ------------------------------------------------------------------

fn small_cfg() -> ServeConfig {
    ServeConfig {
        workers: 2,
        queue_cap: 32,
        max_batch: 4,
        deadline: std::time::Duration::from_millis(1),
        ..ServeConfig::default()
    }
}

#[test]
fn traced_server_emits_loadable_chrome_trace() {
    let plan = Arc::new(
        synthetic_plan("traced", &[16, 24, 6], 4, 8, 0.2, 19).unwrap());
    let rec = TraceRecorder::new();
    let server =
        Server::start_traced(plan, small_cfg(), rec.clone()).unwrap();
    closed_loop(&server, 3, 20, 11).unwrap();
    server.shutdown();

    let json = rec.chrome_trace();
    // the export must survive a serialize -> parse roundtrip (what
    // chrome://tracing and the CI python check do)
    let reparsed = Json::parse(&json.to_string()).unwrap();
    let Json::Arr(events) = reparsed else {
        panic!("chrome trace must be a JSON array");
    };
    assert!(!events.is_empty());
    let mut names = std::collections::BTreeSet::new();
    let mut kernel_slices = 0usize;
    for e in &events {
        let Json::Obj(m) = e else { panic!("event must be an object") };
        for key in ["name", "cat", "ph", "ts", "dur", "pid", "tid",
                    "args"] {
            assert!(m.contains_key(key), "missing {key}: {m:?}");
        }
        assert_eq!(m["ph"], Json::Str("X".into()));
        let (Json::Num(ts), Json::Num(dur)) = (&m["ts"], &m["dur"])
        else {
            panic!("ts/dur must be numbers");
        };
        assert!(*ts >= 0.0 && *dur >= 0.0);
        let Json::Str(name) = &m["name"] else {
            panic!("name must be a string");
        };
        names.insert(name.clone());
        if m["cat"] == Json::Str("kernel".into()) {
            kernel_slices += 1;
            let Json::Obj(args) = &m["args"] else {
                panic!("kernel args must be an object");
            };
            // per-node slices attribute (op, backend, bit widths)
            for key in ["node", "op", "backend", "w_bits", "a_bits"] {
                assert!(args.contains_key(key),
                        "kernel slice missing {key}: {args:?}");
            }
        }
    }
    // all five request phases appear, plus per-node kernel slices
    for phase in ["enqueue", "queue_wait", "batch_form", "infer",
                  "respond"] {
        assert!(names.contains(phase), "missing phase {phase:?} in \
                 {names:?}");
    }
    assert!(kernel_slices > 0, "no per-node kernel slices recorded");
}

#[test]
fn untraced_server_allocates_no_request_ids() {
    let plan = Arc::new(
        synthetic_plan("plain", &[12, 8], 4, 8, 0.0, 23).unwrap());
    let server = Server::start(plan, small_cfg()).unwrap();
    let st = closed_loop(&server, 2, 10, 3).unwrap();
    assert_eq!(st.requests, 20);
    assert_eq!(st.errors, 0);
    server.shutdown();
}

// ------------------------------------------------------------------
// Per-node profiler
// ------------------------------------------------------------------

#[test]
fn profiler_counts_every_node_once_per_batch() {
    let plan = Arc::new(
        synthetic_plan("prof", &[16, 24, 6], 4, 8, 0.2, 29).unwrap());
    let mut eng = Engine::new(plan.clone());
    eng.enable_profiling();
    let xs: Vec<f32> = (0..2 * plan.input_dim)
        .map(|i| ((i as f32) * 0.21).sin())
        .collect();
    let iters = 5u64;
    for _ in 0..iters {
        eng.infer_batch(&xs, 2).unwrap();
    }
    let nodes = eng.node_profile(true);
    assert!(!nodes.is_empty());
    for (id, key, t) in &nodes {
        assert_eq!(t.calls, iters, "node #{id} {key:?}");
        assert!(t.max_ns <= t.total_ns);
    }
    // node ids are unique within one program's profile
    let mut ids: Vec<usize> = nodes.iter().map(|(id, _, _)| *id)
                                   .collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), nodes.len());
    // aggregate rows preserve the total call volume and sort by
    // descending total time
    let rows = eng.kernel_profile(true);
    assert!(!rows.is_empty());
    let agg_calls: u64 = rows.iter().map(|(_, t)| t.calls).sum();
    let node_calls: u64 = nodes.iter().map(|(_, _, t)| t.calls).sum();
    assert_eq!(agg_calls, node_calls);
    for pair in rows.windows(2) {
        assert!(pair[0].1.total_ns >= pair[1].1.total_ns);
    }
    // the f32 path has not run, so its profile is empty
    assert!(eng.node_profile(false).is_empty());
}

#[test]
fn profiling_disabled_engine_matches_profiled_results() {
    let plan = Arc::new(
        synthetic_plan("prof_eq", &[10, 14, 4], 4, 8, 0.1, 31).unwrap());
    let xs: Vec<f32> = (0..3 * plan.input_dim)
        .map(|i| ((i as f32) * 0.4).cos())
        .collect();
    let mut plain = Engine::new(plan.clone());
    let want = plain.infer_batch(&xs, 3).unwrap();
    let mut profiled = Engine::new(plan);
    profiled.enable_profiling();
    let got = profiled.infer_batch(&xs, 3).unwrap();
    assert_eq!(want, got);
}
