//! Focused debug: does one train step move the parameters?
//! Requires `make artifacts`; self-skips when they are absent.

use std::path::Path;

use bayesian_bits::data::{generate, Batcher};
use bayesian_bits::runtime::{Manifest, Runtime, TrainState};

#[test]
fn train_step_moves_params() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("lenet5_manifest.json").exists() {
        eprintln!("skipping: AOT artifacts not built \
                   (run `make artifacts`)");
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            return;
        }
    };
    let man = Manifest::load(&dir, "lenet5").unwrap();
    let exe = rt.load(&man.hlo_train).unwrap();
    let mut state = TrainState::init(&man).unwrap();
    let before = state.params.clone();
    let train = generate(&man.dataset, 1, false).unwrap();
    let mut b = Batcher::new(train, man.batch, false, 1);
    let n_in = man.batch * man.input_shape.iter().product::<usize>();
    let mut x = vec![0.0f32; n_in];
    let mut y = vec![0i32; man.batch];
    let g = man.n_slots;
    let mut last_loss = 0.0;
    let mut last_reg = 0.0;
    for t in 0..20 {
        b.next_into(&mut x, &mut y);
        let out = rt
            .train_step(
                &exe, &man, &mut state, &x, &y, 7 + t,
                (1e-3, 3e-2, 1e-3),
                &vec![0.0; g], &vec![0.0; g], &vec![1e-3; g], 0.0,
            )
            .unwrap();
        if t < 3 || t == 19 {
            eprintln!("t={t} loss={} reg={} probs[0]={} probs[last]={}",
                      out.loss, out.reg, out.probs[0],
                      out.probs[g - 1]);
        }
        last_loss = out.loss;
        last_reg = out.reg;
    }
    let _ = (last_loss, last_reg);
    // group-wise |delta|
    let mut dw = 0.0f64;
    let mut dg = 0.0f64;
    let mut ds = 0.0f64;
    for p in &man.params {
        let d: f64 = (p.offset..p.offset + p.size)
            .map(|i| (state.params[i] - before[i]).abs() as f64)
            .sum();
        match p.group {
            'w' => dw += d,
            'g' => dg += d,
            's' => ds += d,
            _ => {}
        }
    }
    eprintln!("delta by group: w={dw:.6} g={dg:.6} s={ds:.6}");
    assert!(dw > 0.0, "weight parameters did not move");
    assert!(dg > 0.0, "gate parameters did not move");
}
