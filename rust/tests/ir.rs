//! Execution-graph IR invariants: pass-pipeline structure (fusion
//! counts, pruned-channel elision, legacy adapter materialization),
//! arena-assignment safety (no two live buffers may ever alias), and
//! bit-exact equivalence of the interpreter against a manually
//! composed integer pipeline.
//!
//! Pure host subsystem — always runs.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;

use bayesian_bits::engine::graph::{Node, Program};
use bayesian_bits::engine::lower::{self, build_layer};
use bayesian_bits::engine::{synthetic_conv_plan, synthetic_plan,
                            ActSpec, Backend, Engine, EnginePlan};
use bayesian_bits::models::Padding;
use bayesian_bits::quant::grid::quantize_codes_host;
use support::preset_manifest;

fn fused(prog: &Program) -> usize {
    prog.nodes()
        .iter()
        .filter(|n| matches!(n, Node::RequantQuantize { .. }))
        .count()
}

// -------------------------------------------------------------------
// (a) fused quantize/requant node counts for the four model presets
// -------------------------------------------------------------------

#[test]
fn fused_requant_quantize_counts_per_preset() {
    // Every preset layer is w8a8 integer, so each adjacent layer pair
    // with no interstitial op (maxpool/gap/adapt) fuses: the count is
    // (#layers - 1) minus the pairs separated by a pre-op.
    let expect =
        [("lenet5", 1usize), ("vgg7", 4), ("resnet18", 16),
         ("mobilenetv2", 19)];
    for (model, want) in expect {
        let (man, params) = preset_manifest(model, false);
        let plan = Arc::new(lower::lower(&man, &params).unwrap());
        let int_prog = Program::compile(plan.clone(), true);
        assert_eq!(fused(&int_prog), want, "{model} int path");
        assert_eq!(int_prog.fused_count(), want, "{model} accessor");
        // all-integer preset chains have no mid-chain f32 layer, so
        // the epilogue fusion never fires on the int path
        assert_eq!(int_prog.fused_epilogue_count(), 0, "{model}");
        // the f32 reference path never requant-fuses (it has no
        // Requant) — but its epilogues feed the next layer's quantize
        // at exactly the adjacencies the int path requant-fuses, so
        // the epilogue fusion count mirrors the int fusion count
        let f32_prog = Program::compile(plan.clone(), false);
        assert_eq!(fused(&f32_prog), 0, "{model} f32 path");
        assert_eq!(f32_prog.fused_epilogue_count(), want,
                   "{model} f32 epilogue fusion");
        // spatial presets never need the legacy flat adapter
        assert!(
            int_prog
                .nodes()
                .iter()
                .all(|n| !matches!(n, Node::AdaptFeatures { .. })),
            "{model}: unexpected adapt_features node"
        );
        // with fusion on, the int path carries exactly one standalone
        // Quantize per layer whose input is raw f32 (first layer or
        // behind a pre-op)
        let quantizes = int_prog
            .nodes()
            .iter()
            .filter(|n| matches!(n, Node::Quantize { .. }))
            .count();
        assert_eq!(quantizes, plan.layers.len() - want, "{model}");
    }
}

// -------------------------------------------------------------------
// (b) arena slice assignment never aliases two live buffers
// -------------------------------------------------------------------

/// Independently re-derive buffer liveness from the node list and
/// assert that same-dtype buffers with overlapping live ranges were
/// given disjoint arena slices.
fn check_no_aliasing(label: &str, prog: &Program) {
    let nodes = prog.nodes();
    let bufs = prog.bufs();
    let nb = bufs.len();
    let mut def = vec![usize::MAX; nb];
    let mut last = vec![0usize; nb];
    def[prog.input()] = 0;
    for (i, node) in nodes.iter().enumerate() {
        let t = i + 1;
        let w = node.writes();
        if def[w] == usize::MAX {
            def[w] = t;
        }
        if last[w] < t {
            last[w] = t;
        }
        if let Some(r) = node.reads() {
            assert_ne!(def[r], usize::MAX,
                       "{label}: node {i} reads undefined buffer {r}");
            assert!(bufs[r].offset.is_some(),
                    "{label}: node {i} reads unassigned buffer {r}");
            if last[r] < t {
                last[r] = t;
            }
        }
        assert!(bufs[w].offset.is_some(),
                "{label}: node {i} writes unassigned buffer {w}");
    }
    // the caller reads the output after the last node
    last[prog.output()] = nodes.len() + 1;
    assert!(bufs[prog.output()].offset.is_some(), "{label}: output");

    for a in 0..nb {
        for b in a + 1..nb {
            let (ba, bb) = (&bufs[a], &bufs[b]);
            if ba.dtype != bb.dtype {
                continue;
            }
            let (Some(oa), Some(ob)) = (ba.offset, bb.offset) else {
                continue;
            };
            if def[a] == usize::MAX || def[b] == usize::MAX {
                continue;
            }
            let live_overlap = def[a] <= last[b] && def[b] <= last[a];
            if !live_overlap {
                continue;
            }
            let disjoint = oa + ba.len <= ob || ob + bb.len <= oa;
            assert!(
                disjoint,
                "{label}: live buffers {a} [{oa}..{}] and {b} \
                 [{ob}..{}] alias",
                oa + ba.len,
                ob + bb.len
            );
        }
    }
}

#[test]
fn arena_assignment_never_aliases_live_buffers() {
    let mut programs: Vec<(String, Program)> = Vec::new();
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let (man, params) = preset_manifest(model, false);
        let plan = Arc::new(lower::lower(&man, &params).unwrap());
        programs.push((format!("{model}/int"),
                       Program::compile(plan.clone(), true)));
        programs.push((format!("{model}/f32"),
                       Program::compile(plan, false)));
    }
    // the legacy flattened manifest exercises the AdaptFeatures path
    let (man, params) = preset_manifest("lenet5", true);
    let plan = Arc::new(lower::lower(&man, &params).unwrap());
    let legacy = Program::compile(plan.clone(), true);
    assert!(
        legacy
            .nodes()
            .iter()
            .any(|n| matches!(n, Node::AdaptFeatures { .. })),
        "legacy manifest must materialize the flat adapter"
    );
    programs.push(("lenet5-legacy/int".into(), legacy));
    programs.push(("lenet5-legacy/f32".into(),
                   Program::compile(plan, false)));
    // synthetic shapes: pruned dense chain, conv, depthwise
    let plan = Arc::new(
        synthetic_plan("chain", &[16, 32, 32, 10], 4, 8, 0.4, 5)
            .unwrap());
    programs.push(("chain/int".into(),
                   Program::compile(plan.clone(), true)));
    programs.push(("chain/f32".into(), Program::compile(plan, false)));
    let plan = Arc::new(
        synthetic_conv_plan("conv", 7, 3, 6, 3, 2, Padding::Same, 1, 4,
                            8, 0.3, 9)
            .unwrap());
    programs.push(("conv/int".into(),
                   Program::compile(plan.clone(), true)));
    programs.push(("conv/f32".into(), Program::compile(plan, false)));
    let plan = Arc::new(
        synthetic_conv_plan("dw", 6, 4, 4, 3, 1, Padding::Same, 4, 4, 8,
                            0.25, 13)
            .unwrap());
    programs.push(("dw/int".into(), Program::compile(plan, true)));

    for (label, prog) in &programs {
        check_no_aliasing(label, prog);
        // the packed arena is never larger than the sum of its live
        // buffers, and never smaller than the true peak
        assert!(prog.arena_bytes() >= prog.peak_live_bytes(), "{label}");
    }
}

#[test]
fn arena_reuse_beats_one_slot_per_buffer() {
    let plan = Arc::new(
        synthetic_plan("deep", &[32, 64, 64, 64, 10], 4, 8, 0.25, 9)
            .unwrap());
    let prog = Program::compile(plan, true);
    let naive: usize = prog
        .bufs()
        .iter()
        .filter(|b| b.offset.is_some())
        .map(|b| b.len * b.dtype.bytes())
        .sum();
    assert!(
        prog.arena_bytes() < naive,
        "no reuse: arena {} vs naive {naive}",
        prog.arena_bytes()
    );
}

// -------------------------------------------------------------------
// interpreter vs a manually composed integer pipeline (bit-exact)
// -------------------------------------------------------------------

/// Straight-line reimplementation of the integer datapath for dense
/// chains: quantize on the layer grid, exact i64 dot over unpacked
/// codes, one requantize multiply, bias, ReLU. Mirrors the engine's
/// float-operation order exactly, so results must match bit-for-bit —
/// fused or not.
fn manual_int_reference(plan: &EnginePlan, x: &[f32]) -> Vec<f32> {
    let mut cur = x.to_vec();
    for l in &plan.layers {
        let mut next = match &l.bias {
            Some(b) => b.clone(),
            None => vec![0.0f32; l.out_dim],
        };
        if !l.kept.is_empty() {
            let ActSpec::Int { bits, beta, signed } = l.act else {
                panic!("manual reference needs integer activations")
            };
            let (step, codes) =
                quantize_codes_host(&cur, beta, bits, signed);
            let wcodes = l.packed.as_ref().unwrap().unpack();
            let scale = l.w_scale as f64 * step as f64;
            for (k, ch) in l.kept.iter().enumerate() {
                let mut acc = 0i64;
                for c in 0..l.in_dim {
                    acc += wcodes[k * l.in_dim + c] * codes[c];
                }
                next[*ch as usize] += (acc as f64 * scale) as f32;
            }
        }
        if l.relu {
            for v in next.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        cur = next;
    }
    cur
}

fn dense_chain_plan(prune_middle: bool) -> EnginePlan {
    let mut rng = bayesian_bits::rng::Pcg64::new(31);
    let mut w = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() * 0.5).collect()
    };
    let l1 = build_layer(
        "fc1", &w(6 * 5), 6, 5, &[1.0, 0.0, 1.0, 1.0, 1.0], 4, 1.5,
        ActSpec::Int { bits: 8, beta: 3.0, signed: true },
        Some(vec![0.1, -0.2, 0.3, -0.4, 0.5]), true)
        .unwrap();
    let z2 = if prune_middle {
        vec![0.0f32; 4]
    } else {
        vec![1.0, 1.0, 0.0, 1.0]
    };
    let l2 = build_layer(
        "fc2", &w(5 * 4), 5, 4, &z2, 4, 1.5,
        ActSpec::Int { bits: 8, beta: 6.0, signed: false },
        Some(vec![0.25, -0.5, 0.75, 1.0]), true)
        .unwrap();
    let l3 = build_layer(
        "fc3", &w(4 * 3), 4, 3, &[1.0, 1.0, 1.0], 8, 1.5,
        ActSpec::Int { bits: 8, beta: 6.0, signed: false },
        Some(vec![0.0, 0.1, -0.1]), false)
        .unwrap();
    let plan = EnginePlan {
        model: "manual".into(),
        input_dim: 6,
        output_dim: 3,
        layers: vec![l1, l2, l3],
    };
    plan.validate().unwrap();
    plan
}

#[test]
fn ir_executor_matches_manual_integer_pipeline_bit_exactly() {
    for prune_middle in [false, true] {
        let plan = Arc::new(dense_chain_plan(prune_middle));
        let prog = Program::compile(plan.clone(), true);
        if prune_middle {
            // pruned-channel elision: the dead layer keeps only its
            // BiasFill, so neither fusion partner survives around it
            assert_eq!(fused(&prog), 0);
            assert!(prog
                .nodes()
                .iter()
                .any(|n| matches!(n, Node::BiasFill { .. })));
            let gemms = prog
                .nodes()
                .iter()
                .filter(|n| matches!(n, Node::Gemm { .. }))
                .count();
            assert_eq!(gemms, 2, "pruned layer's kernel must be elided");
        } else {
            // two adjacent integer pairs -> two fused nodes
            assert_eq!(fused(&prog), 2);
        }
        let mut eng = Engine::new(plan.clone());
        for t in 0..8 {
            let x: Vec<f32> = (0..6)
                .map(|i| ((t * 6 + i) as f32 * 0.41).sin() * 2.5)
                .collect();
            let got = eng.infer(&x).unwrap();
            let want = manual_int_reference(&plan, &x);
            assert_eq!(got, want, "prune_middle={prune_middle} t={t}");
        }
        // batching three copies reproduces each row bit-exactly
        let x: Vec<f32> =
            (0..6).map(|i| (i as f32 * 0.7).cos()).collect();
        let one = eng.infer(&x).unwrap();
        let mut xs = x.clone();
        xs.extend_from_slice(&x);
        xs.extend_from_slice(&x);
        let batch = eng.infer_batch(&xs, 3).unwrap();
        for r in 0..3 {
            assert_eq!(&batch[r * 3..(r + 1) * 3], &one[..], "row {r}");
        }
    }
}

/// A chain whose head is a 32-bit layer (`packed: None` — lowered to
/// an f32 kernel + `Epilogue` even on the int path) feeding two
/// integer layers: the mixed f32/int shape the epilogue fusion
/// targets.
fn mixed_chain_plan() -> EnginePlan {
    let mut rng = bayesian_bits::rng::Pcg64::new(77);
    let mut w = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal() * 0.5).collect()
    };
    let l1 = build_layer(
        "fp1", &w(6 * 5), 6, 5, &[1.0; 5], 32, 1.5,
        ActSpec::Int { bits: 8, beta: 3.0, signed: true },
        Some(vec![0.1, -0.2, 0.3, -0.4, 0.5]), true)
        .unwrap();
    let l2 = build_layer(
        "int2", &w(5 * 4), 5, 4, &[1.0; 4], 4, 1.5,
        ActSpec::Int { bits: 8, beta: 6.0, signed: false },
        Some(vec![0.25, -0.5, 0.75, 1.0]), true)
        .unwrap();
    let l3 = build_layer(
        "int3", &w(4 * 3), 4, 3, &[1.0; 3], 8, 1.5,
        ActSpec::Int { bits: 8, beta: 6.0, signed: false },
        Some(vec![0.0, 0.1, -0.1]), false)
        .unwrap();
    let plan = EnginePlan {
        model: "mixed".into(),
        input_dim: 6,
        output_dim: 3,
        layers: vec![l1, l2, l3],
    };
    plan.validate().unwrap();
    plan
}

#[test]
fn epilogue_quantize_fuses_on_mixed_f32_int_chains() {
    let plan = Arc::new(mixed_chain_plan());
    let prog = Program::compile(plan.clone(), true);
    // the w32 head's epilogue feeds the next integer layer's quantize
    // and fuses; the int2 -> int3 pair still requant-fuses
    assert_eq!(prog.fused_epilogue_count(), 1, "int path");
    assert_eq!(fused(&prog), 1, "int path requant fusion");
    assert_eq!(prog.fused_count(), 2, "int path accessor");
    assert!(prog
        .nodes()
        .iter()
        .any(|n| matches!(n, Node::EpilogueQuantize { .. })));
    assert!(prog.dump().contains("epilogue_quantize"),
            "{}", prog.dump());
    // the f32 reference path lowers every layer to kernel + epilogue,
    // so both adjacencies epilogue-fuse there
    let f32_prog = Program::compile(plan.clone(), false);
    assert_eq!(f32_prog.fused_epilogue_count(), 2, "f32 path");
    assert_eq!(fused(&f32_prog), 0, "f32 path never requant-fuses");
    // the fused datapath stays bit-exact across kernel backends,
    // including blocked panels sharded over intra-request threads
    let mut scalar =
        Engine::with_backend(plan.clone(), Some(Backend::Scalar));
    let mut simd =
        Engine::with_backend(plan.clone(), Some(Backend::Simd));
    let mut blocked =
        Engine::with_backend(plan.clone(), Some(Backend::Blocked));
    blocked.set_intra_threads(2);
    for t in 0..6 {
        let x: Vec<f32> = (0..6)
            .map(|i| ((t * 6 + i) as f32 * 0.53).sin() * 2.0)
            .collect();
        let a = scalar.infer(&x).unwrap();
        assert_eq!(a, simd.infer(&x).unwrap(), "simd t={t}");
        assert_eq!(a, blocked.infer(&x).unwrap(), "blocked t={t}");
    }
}

#[test]
fn dump_lists_nodes_and_arena_map() {
    let (man, params) = preset_manifest("lenet5", false);
    let plan = Arc::new(lower::lower(&man, &params).unwrap());
    let prog = Program::compile_with_backend(plan.clone(), true,
                                             Some(Backend::Simd));
    let dump = prog.dump();
    assert!(dump.contains("lenet5"), "{dump}");
    assert!(dump.contains("arena"), "{dump}");
    assert!(dump.contains("maxpool2"), "{dump}");
    assert!(dump.contains("requant_quantize"), "{dump}");
    assert!(dump.contains("conv1"), "{dump}");
    // kernel nodes print their backend discriminant (CI greps this)
    assert!(dump.contains("conv2d.simd"), "{dump}");
    assert!(dump.contains("gemm.simd"), "{dump}");
    // one line per node plus header/footer
    assert!(dump.lines().count() >= prog.nodes().len() + 3, "{dump}");
    // the blocked compile prints .blocked kernel names (CI greps
    // these too) and is the only compile that carries weight panels
    let blocked = Program::compile_with_backend(plan.clone(), true,
                                                Some(Backend::Blocked));
    let bdump = blocked.dump();
    assert!(bdump.contains("conv2d.blocked"), "{bdump}");
    assert!(bdump.contains("gemm.blocked"), "{bdump}");
    assert!(!bdump.contains(".simd"), "{bdump}");
    assert!(blocked.panel_bytes() > 0);
    assert_eq!(prog.panel_bytes(), 0);
    // the scalar compile prints undecorated kernel names
    let prog = Program::compile_with_backend(plan, true,
                                             Some(Backend::Scalar));
    let dump = prog.dump();
    assert!(!dump.contains(".simd"), "{dump}");
    assert!(!dump.contains(".blocked"), "{dump}");
    assert!(dump.contains("conv2d"), "{dump}");
}

// -------------------------------------------------------------------
// pass-stable node ids (profiler attribution)
// -------------------------------------------------------------------

#[test]
fn node_ids_are_unique_deterministic_and_backend_invariant() {
    let mut plans: Vec<(String, Arc<EnginePlan>)> = Vec::new();
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let (man, params) = preset_manifest(model, false);
        plans.push((model.into(),
                    Arc::new(lower::lower(&man, &params).unwrap())));
    }
    plans.push(("pruned-chain".into(), Arc::new(
        synthetic_plan("chain", &[16, 32, 32, 10], 4, 8, 0.4, 5)
            .unwrap())));
    plans.push(("dw".into(), Arc::new(
        synthetic_conv_plan("dw", 6, 4, 4, 3, 1, Padding::Same, 4, 4,
                            8, 0.25, 13).unwrap())));
    for (label, plan) in &plans {
        for int_path in [true, false] {
            let prog = Program::compile(plan.clone(), int_path);
            let ids = prog.node_ids();
            // one id per node, all distinct (unique profiler keys)
            assert_eq!(ids.len(), prog.nodes().len(), "{label}");
            let mut sorted = ids.to_vec();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), ids.len(),
                       "{label}/{int_path}: duplicate node ids");
            // recompiling the same plan reproduces the same ids
            let again = Program::compile(plan.clone(), int_path);
            assert_eq!(again.node_ids(), ids, "{label}/{int_path}");
        }
        // the backend choice relabels kernels but must not renumber
        // them — profiles across backends stay comparable per node
        let scalar = Program::compile_with_backend(
            plan.clone(), true, Some(Backend::Scalar));
        let simd = Program::compile_with_backend(
            plan.clone(), true, Some(Backend::Simd));
        let blocked = Program::compile_with_backend(
            plan.clone(), true, Some(Backend::Blocked));
        assert_eq!(scalar.node_ids(), simd.node_ids(), "{label}");
        assert_eq!(scalar.node_ids(), blocked.node_ids(), "{label}");
    }
}

#[test]
fn fusion_retires_ids_instead_of_renumbering() {
    // dense_chain_plan(false) fuses twice; the surviving ids must be a
    // subset of a hypothetical unfused numbering (i.e. fusion removes
    // ids, it never shifts the survivors), which shows up as gaps
    // rather than a dense 0..n range
    let plan = Arc::new(dense_chain_plan(false));
    let prog = Program::compile(plan.clone(), true);
    assert_eq!(fused(&prog), 2);
    let ids = prog.node_ids();
    let max_id = *ids.iter().max().unwrap();
    assert!(max_id >= ids.len(),
            "two fused ids must retire: max {max_id} over {} nodes",
            ids.len());
    // the dump carries the stable id of every node
    let dump = prog.dump();
    for id in ids {
        assert!(dump.contains(&format!("#{id}")), "{dump}");
    }
}

#[test]
fn backend_auto_rule_splits_on_lane_width() {
    use bayesian_bits::engine::kernels::LANES;
    // sub-lane rows stay scalar, lane-filling rows go SIMD — only
    // when nothing forces a backend
    let plan = Arc::new(
        synthetic_plan("mix", &[LANES - 1, LANES, 4 * LANES, 10], 4, 8,
                       0.0, 3)
            .unwrap());
    let prog = Program::compile_with_backend(plan.clone(), true, None);
    if std::env::var("BBITS_BACKEND").is_err() {
        let got: Vec<Backend> = prog
            .nodes()
            .iter()
            .filter_map(|n| n.backend())
            .collect();
        // layer widths (in_dim) are LANES-1, LANES, 4*LANES — and the
        // auto rule never picks Blocked (the panel form is opt-in)
        assert_eq!(got,
                   vec![Backend::Scalar, Backend::Simd, Backend::Simd]);
    }
    // a forced compile overrides the rule on every kernel node
    for forced in [Backend::Scalar, Backend::Simd, Backend::Blocked] {
        let prog = Program::compile_with_backend(plan.clone(), true,
                                                 Some(forced));
        for n in prog.nodes() {
            if let Some(b) = n.backend() {
                assert_eq!(b, forced);
            }
        }
    }
    // the f32 reference path never carries a SIMD kernel
    let prog = Program::compile_with_backend(plan, false,
                                             Some(Backend::Simd));
    for n in prog.nodes() {
        assert_ne!(n.backend(), Some(Backend::Simd), "{}",
                   n.op_name());
    }
}
