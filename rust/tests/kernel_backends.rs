//! Kernel backend differential battery: every preset manifest plus
//! randomized layer shapes run through the scalar oracle, the SIMD
//! backend, and the cache-blocked panel backend (single-threaded and
//! intra-request sharded), asserting **bit-exact** logits —
//! including the blocked-i32 `low_bit` path, the widening i64 path,
//! and pruned `kept` subsets. Also the paper-scale ResNet18 lowering
//! check (the ROADMAP's missing end-to-end test): the full 224x224
//! manifest lowers through the IR under every backend with
//! backend-invariant structure and memory accounting, and the
//! committed golden fixture is pinned bit-exact under the forced
//! SIMD and blocked compiles.
//!
//! Pure host subsystem — always runs. Every backend computes the
//! same exact integer accumulators as the scalar kernels (integer
//! addition is associative, so panel/tile/shard order cannot move a
//! sum), so any mismatch here is a backend bug, never a tolerance
//! question.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;

use bayesian_bits::engine::graph::Program;
use bayesian_bits::engine::kernels::LANES;
use bayesian_bits::engine::{lower, synthetic_conv_plan,
                            synthetic_plan, Backend, Engine,
                            EnginePlan};
use bayesian_bits::models::{Padding, Preset};
use bayesian_bits::rng::Pcg64;
use bayesian_bits::runtime::manifest_gen::preset_manifest_at;
use support::{golden_fixture, preset_manifest};

/// Run `n` random inputs through all three backends (int path) and
/// assert bit-exact logits — the blocked backend both single-threaded
/// and sharded across intra-request threads; also asserts the forced
/// compiles really do carry the forced kernel nodes, so the battery
/// cannot silently compare scalar against scalar.
fn assert_backends_bit_exact(label: &str, plan: Arc<EnginePlan>,
                             n: usize, seed: u64) {
    let mut scalar =
        Engine::with_backend(plan.clone(), Some(Backend::Scalar));
    let mut simd =
        Engine::with_backend(plan.clone(), Some(Backend::Simd));
    let mut blocked =
        Engine::with_backend(plan.clone(), Some(Backend::Blocked));
    let forced_kernels = |eng: &Engine, b: Backend| {
        eng.program(true)
            .nodes()
            .iter()
            .filter(|nd| nd.backend() == Some(b))
            .count()
    };
    // integer kernel nodes only — an f32 kernel inside the int
    // program (32-bit chain end) has no SIMD or blocked form
    let kernels_total = simd
        .program(true)
        .nodes()
        .iter()
        .filter(|nd| nd.backend().is_some()
            && !nd.op_name().ends_with(".f32"))
        .count();
    assert_eq!(forced_kernels(&simd, Backend::Simd), kernels_total,
               "{label}: forced simd compile left scalar kernel nodes");
    assert_eq!(forced_kernels(&blocked, Backend::Blocked),
               kernels_total,
               "{label}: forced blocked compile left other kernels");
    assert_eq!(forced_kernels(&scalar, Backend::Simd), 0,
               "{label}: forced scalar compile has SIMD nodes");
    // a blocked program over any integer kernel carries its weight
    // panels; the scalar/simd compiles never pay for them
    if kernels_total > 0 {
        assert!(blocked.program(true).panel_bytes() > 0,
                "{label}: blocked compile built no panels");
    }
    assert_eq!(scalar.program(true).panel_bytes(), 0, "{label}");
    assert_eq!(simd.program(true).panel_bytes(), 0, "{label}");

    let mut rng = Pcg64::new(seed);
    let xs: Vec<f32> = (0..n * plan.input_dim)
        .map(|_| rng.normal() * 2.0)
        .collect();
    let a = scalar.infer_batch(&xs, n).unwrap();
    let b = simd.infer_batch(&xs, n).unwrap();
    assert_eq!(a, b, "{label}: scalar vs simd logits diverged");
    // blocked, single-threaded then sharded — thread counts chosen to
    // straddle shard boundaries (2 splits evenly, 3 leaves remainders,
    // 5 exceeds many plans' kept-row/tile counts so some shards are
    // empty)
    for threads in [1usize, 2, 3, 5] {
        blocked.set_intra_threads(threads);
        let c = blocked.infer_batch(&xs, n).unwrap();
        assert_eq!(a, c,
                   "{label}: scalar vs blocked(intra={threads}) \
                    logits diverged");
    }
    // single-sample inference agrees with its batched row too
    let one_s = scalar.infer(&xs[..plan.input_dim]).unwrap();
    let one_v = simd.infer(&xs[..plan.input_dim]).unwrap();
    let one_b = blocked.infer(&xs[..plan.input_dim]).unwrap();
    assert_eq!(one_s, one_v, "{label}: single-sample mismatch");
    assert_eq!(one_s, one_b, "{label}: single-sample blocked mismatch");
    assert_eq!(one_v, a[..plan.output_dim].to_vec(), "{label}");
}

// -------------------------------------------------------------------
// (a) every preset manifest, spatial and legacy-flat lowering
// -------------------------------------------------------------------

#[test]
fn preset_manifests_bit_exact_across_backends() {
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let (man, params) = preset_manifest(model, false);
        let plan = Arc::new(lower(&man, &params).unwrap());
        assert_backends_bit_exact(model, plan, 3, 101);
    }
    // the legacy flattened schema exercises the AdaptFeatures bridge
    let (man, params) = preset_manifest("lenet5", true);
    let plan = Arc::new(lower(&man, &params).unwrap());
    assert_backends_bit_exact("lenet5-legacy", plan, 3, 103);
}

// -------------------------------------------------------------------
// (b) randomized dense chains: low-bit and wide paths, pruning
// -------------------------------------------------------------------

#[test]
fn randomized_dense_chains_bit_exact_across_backends() {
    let mut rng = Pcg64::new(7);
    for trial in 0..12 {
        let depth = 2 + (rng.next_u64() % 3) as usize;
        let mut dims = Vec::with_capacity(depth + 1);
        for _ in 0..=depth {
            // widths straddling the 8-lane width, incl. sub-lane
            dims.push(1 + (rng.next_u64() % (3 * LANES as u64 + 2))
                as usize);
        }
        let w_bits = [2u32, 4, 8, 16][(rng.next_u64() % 4) as usize];
        // 16-bit activations force the widening i64 accumulators
        let a_bits = if w_bits == 16 { 16 } else { 8 };
        let prune = if trial % 2 == 0 { 0.4 } else { 0.0 };
        let plan = Arc::new(
            synthetic_plan(&format!("rand{trial}"), &dims, w_bits,
                           a_bits, prune, 1000 + trial)
                .unwrap(),
        );
        assert_backends_bit_exact(
            &format!("dense t{trial} w{w_bits}a{a_bits} {dims:?}"),
            plan, 2, 200 + trial);
    }
}

// -------------------------------------------------------------------
// (c) randomized conv / depthwise shapes across the stride-padding-
//     groups grid
// -------------------------------------------------------------------

#[test]
fn randomized_conv_shapes_bit_exact_across_backends() {
    let mut rng = Pcg64::new(11);
    for trial in 0..10u64 {
        let hw = 4 + (rng.next_u64() % 5) as usize;
        let k = 1 + (rng.next_u64() % 3) as usize;
        let stride = 1 + (rng.next_u64() % 2) as usize;
        let padding = if rng.next_u64() % 2 == 0 {
            Padding::Same
        } else {
            Padding::Valid
        };
        if padding == Padding::Valid && hw < k {
            continue;
        }
        // group counts that do not divide the lane width (1, 2, 3)
        let groups = 1 + (rng.next_u64() % 3) as usize;
        let cg = 1 + (rng.next_u64() % 4) as usize;
        let cin = groups * cg;
        let cout = groups * (1 + (rng.next_u64() % 11) as usize);
        let w_bits = [2u32, 4, 8, 16][(rng.next_u64() % 4) as usize];
        let a_bits = if trial % 3 == 0 { 16 } else { 8 };
        let plan = Arc::new(
            synthetic_conv_plan(&format!("conv{trial}"), hw, cin, cout,
                                k, stride, padding, groups, w_bits,
                                a_bits, 0.3, 300 + trial)
                .unwrap(),
        );
        assert_backends_bit_exact(
            &format!("conv t{trial} hw{hw} k{k} s{stride} g{groups} \
                      w{w_bits}a{a_bits}"),
            plan, 2, 400 + trial);
    }
    // depthwise with pruned channels, rows straddling the lane width
    for (c, prune) in [(LANES + 3, 0.3), (2 * LANES + 1, 0.5),
                       (3, 0.0)] {
        let plan = Arc::new(
            synthetic_conv_plan("dw", 6, c, c, 3, 1, Padding::Same, c,
                                4, 8, prune, 500 + c as u64)
                .unwrap(),
        );
        assert_backends_bit_exact(&format!("dwconv c{c}"), plan, 2,
                                  600 + c as u64);
    }
}

// -------------------------------------------------------------------
// (d) paper-scale ResNet18: lower the full-size manifest through the
//     IR under both backends (the ROADMAP's missing e2e test)
// -------------------------------------------------------------------

#[test]
fn paper_scale_resnet18_lowering_is_backend_invariant() {
    // ~11M weights: debug-mode quantize+pack is a CI hotspot (the
    // suite runs twice, once per BBITS_BACKEND), so the paper-scale
    // build runs in optimized tests only — CI runs this suite again
    // under --release, where it executes unconditionally.
    if cfg!(debug_assertions)
        && std::env::var("BBITS_PAPER_SCALE").is_err()
    {
        eprintln!("skipping paper-scale lowering in a debug build \
                   (set BBITS_PAPER_SCALE=1 to force)");
        return;
    }
    let (man, params) =
        preset_manifest_at("resnet18", false, 42, Preset::Paper)
            .unwrap();
    let plan = Arc::new(lower(&man, &params).unwrap());
    assert_eq!(plan.input_dim, 224 * 224 * 3);
    assert_eq!(plan.output_dim, 1000);

    let int_scalar = Program::compile_with_backend(
        plan.clone(), true, Some(Backend::Scalar));
    let int_simd = Program::compile_with_backend(
        plan.clone(), true, Some(Backend::Simd));
    let int_blocked = Program::compile_with_backend(
        plan.clone(), true, Some(Backend::Blocked));
    // backend choice is purely a kernel-dispatch property: graph
    // structure, fusion, and memory accounting must not move
    assert_eq!(int_scalar.nodes().len(), int_simd.nodes().len());
    assert_eq!(int_scalar.nodes().len(), int_blocked.nodes().len());
    assert_eq!(int_scalar.fused_count(), int_simd.fused_count());
    assert_eq!(int_scalar.fused_count(), int_blocked.fused_count());
    assert_eq!(int_scalar.arena_bytes(), int_simd.arena_bytes());
    assert_eq!(int_scalar.arena_bytes(), int_blocked.arena_bytes());
    assert_eq!(int_scalar.peak_live_bytes(),
               int_simd.peak_live_bytes());
    // the blocked compile additionally carries decoded weight panels
    // (charged separately from the arena), the others never do
    assert!(int_blocked.panel_bytes() > 0);
    assert_eq!(int_scalar.panel_bytes(), 0);
    assert_eq!(int_simd.panel_bytes(), 0);
    // the paper-scale graph fuses exactly like the small preset: the
    // layer topology is scale-independent
    let (sman, sparams) = preset_manifest("resnet18", false);
    let splan = Arc::new(lower(&sman, &sparams).unwrap());
    let small = Program::compile_with_backend(
        splan, true, Some(Backend::Simd));
    assert_eq!(int_simd.fused_count(), small.fused_count());
    // every paper-scale kernel's lane dimension clears LANES, so the
    // auto rule (no force) picks SIMD throughout
    let auto = Program::compile_with_backend(plan.clone(), true, None);
    if std::env::var("BBITS_BACKEND").is_err() {
        for nd in auto.nodes() {
            if let Some(b) = nd.backend() {
                assert_eq!(b, Backend::Simd, "{}", nd.op_name());
            }
        }
    }
    // ... and never picks Blocked either: the panel form is opt-in
    if std::env::var("BBITS_BACKEND").is_err() {
        for nd in auto.nodes() {
            assert_ne!(nd.backend(), Some(Backend::Blocked),
                       "auto rule picked blocked for {}",
                       nd.op_name());
        }
    }
    // the f32 reference path never carries SIMD nodes
    let f32_prog = Program::compile_with_backend(
        plan.clone(), false, Some(Backend::Simd));
    for nd in f32_prog.nodes() {
        assert_ne!(nd.backend(), Some(Backend::Simd),
                   "f32 path node {} got a SIMD backend",
                   nd.op_name());
    }
    // one measured paper-scale forward: the blocked backend, sharded
    // across two intra-request threads, must reproduce the scalar
    // oracle's 1000-way logits bit-for-bit end to end
    let mut scalar =
        Engine::with_backend(plan.clone(), Some(Backend::Scalar));
    let mut blocked =
        Engine::with_backend(plan.clone(), Some(Backend::Blocked));
    blocked.set_intra_threads(2);
    let xs: Vec<f32> = (0..plan.input_dim)
        .map(|i| ((i as f32) * 0.37).sin())
        .collect();
    let want = scalar.infer(&xs).unwrap();
    let got = blocked.infer(&xs).unwrap();
    assert_eq!(want, got,
               "paper-scale resnet18: blocked(intra=2) diverged from \
                the scalar oracle");
}

// -------------------------------------------------------------------
// (e) golden fixture pinned bit-exact under the forced-SIMD compile
// -------------------------------------------------------------------

#[test]
fn golden_fixture_bit_exact_under_simd_backend() {
    let (man, params, exp) = golden_fixture();
    let plan = Arc::new(lower(&man, &params).unwrap());
    let mut eng =
        Engine::with_backend(plan.clone(), Some(Backend::Simd));
    let inputs: Vec<Vec<f32>> = exp
        .get("inputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.f32_vec().unwrap())
        .collect();
    let logits: Vec<Vec<f32>> = exp
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.f32_vec().unwrap())
        .collect();
    for (x, want) in inputs.iter().zip(&logits) {
        let got = eng.infer(x).unwrap();
        assert_eq!(&got, want, "simd backend vs golden fixture");
    }
    // whole fixture as one batch, still bit-exact
    let flat: Vec<f32> =
        inputs.iter().flat_map(|x| x.iter().copied()).collect();
    let batched = eng.infer_batch(&flat, inputs.len()).unwrap();
    for (i, want) in logits.iter().enumerate() {
        assert_eq!(&batched[i * want.len()..(i + 1) * want.len()],
                   &want[..], "simd batched row {i}");
    }
}

// -------------------------------------------------------------------
// (f) golden fixture pinned bit-exact under the forced-blocked
//     compile, at every intra-thread count
// -------------------------------------------------------------------

#[test]
fn golden_fixture_bit_exact_under_blocked_backend() {
    let (man, params, exp) = golden_fixture();
    let plan = Arc::new(lower(&man, &params).unwrap());
    let mut eng =
        Engine::with_backend(plan.clone(), Some(Backend::Blocked));
    let inputs: Vec<Vec<f32>> = exp
        .get("inputs")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.f32_vec().unwrap())
        .collect();
    let logits: Vec<Vec<f32>> = exp
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.f32_vec().unwrap())
        .collect();
    for threads in [1usize, 2, 4] {
        eng.set_intra_threads(threads);
        for (x, want) in inputs.iter().zip(&logits) {
            let got = eng.infer(x).unwrap();
            assert_eq!(&got, want,
                       "blocked(intra={threads}) vs golden fixture");
        }
    }
}
