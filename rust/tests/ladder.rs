//! Precision-ladder battery: one trained posterior lowered at several
//! Eq. 22 gate thresholds must yield genuinely different bit-width
//! rungs, every rung must serve bit-exactly against a direct
//! `lower_with_mode_at` oracle — including after LRU eviction and
//! recompilation — and the rung-pick policy must degrade precision
//! monotonically with queue pressure, never shedding upward.
//!
//! The preset manifests init gate logits at saturated +/-6, where
//! every reasonable threshold produces the same plan; these tests move
//! phi onto intermediate posteriors so the ladder actually fans out.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;
use std::time::Duration;

use bayesian_bits::config::Mode;
use bayesian_bits::engine::registry::{pick_rung, ModelRegistry,
                                      RungLoad};
use bayesian_bits::engine::serve::ServeConfig;
use bayesian_bits::engine::{lower, Engine};
use bayesian_bits::quant::gates::{GAMMA, TAU, ZETA};
use bayesian_bits::runtime::Manifest;
use support::preset_manifest;

/// Ascending thresholds chosen around the perturbed posteriors below:
/// 0.2 opens nothing past z2 (w2), 0.5 opens z4 (w4), 0.9 opens z8
/// (w8).
const LADDER: [f64; 3] = [0.2, 0.5, 0.9];

fn cfg() -> ServeConfig {
    ServeConfig {
        workers: 1,
        queue_cap: 16,
        max_batch: 2,
        deadline: Duration::from_micros(200),
        ..ServeConfig::default()
    }
}

/// Gate logit whose Eq. 22 inactive probability is exactly `p0`: the
/// test-time gate opens iff the threshold exceeds `p0`.
fn phi_for_p0(p0: f64) -> f32 {
    let c = TAU * (-GAMMA / ZETA).ln();
    (c - (p0 / (1.0 - p0)).ln()) as f32
}

/// lenet5 preset with gate logits moved to intermediate posteriors:
/// weight z4 residuals sit at p0 = 0.25 and z8 at p0 = 0.6, deeper
/// residuals near-closed, channel gates and activation residuals up
/// to 8 bits near-open. Every [`LADDER`] rung then shares its kept
/// channel sets and a8 activations but differs in weight bits.
fn laddered_manifest() -> (Manifest, Vec<f32>) {
    let (man, mut params) = preset_manifest("lenet5", false);
    let idx = man.phi_index();
    for q in &man.quantizers {
        for i in 0..q.n_slots {
            let p0 = if i < q.channels {
                0.05
            } else {
                match (q.kind, i - q.channels) {
                    ('w', 0) => 0.25,
                    ('w', 1) => 0.60,
                    ('a', 0) | ('a', 1) => 0.05,
                    _ => 0.95,
                }
            };
            params[idx[q.offset + i]] = phi_for_p0(p0);
        }
    }
    (man, params)
}

fn input(dim: usize, salt: usize) -> Vec<f32> {
    (0..dim)
        .map(|j| ((salt * dim + j) as f32 * 0.23).sin().abs())
        .collect()
}

#[test]
fn ladder_rungs_are_bit_exact_vs_direct_lowering_across_eviction() {
    let (man, params) = laddered_manifest();
    let registry = Arc::new(ModelRegistry::with_budget(0));
    // registration order must not matter: pass thresholds shuffled
    registry
        .register_ladder("mdl", &man, &params, &Mode::BayesianBits,
                         &[0.9, 0.2, 0.5], cfg())
        .unwrap();
    let info = registry.ladder("mdl").unwrap();
    assert_eq!(info.len(), 3);
    // rungs ascend in threshold, bit width, and proxy score
    for w in info.windows(2) {
        assert!(w[0].threshold < w[1].threshold);
        assert!(w[0].w_bits < w[1].w_bits,
                "{} vs {}", w[0].label, w[1].label);
        assert!(w[0].score < w[1].score,
                "{} vs {}", w[0].label, w[1].label);
    }
    assert_eq!((info[0].w_bits, info[1].w_bits, info[2].w_bits),
               (2, 4, 8));

    // direct-lowering oracle per rung
    let mut oracles: Vec<Engine> = LADDER
        .iter()
        .map(|t| {
            Engine::new(Arc::new(
                lower::lower_with_mode_at(&man, &params,
                                          &Mode::BayesianBits, *t)
                    .unwrap(),
            ))
        })
        .collect();
    // distinct rungs really compute different numbers somewhere
    let dim = registry.plan("mdl").unwrap().input_dim;
    let probe = input(dim, 99);
    assert_ne!(oracles[0].infer(&probe).unwrap(),
               oracles[2].infer(&probe).unwrap(),
               "w2 and w8 rungs should disagree on some input");

    // alternate rungs under a zero byte budget: every switch evicts
    // the previous rung and recompiles the next, and the responses
    // stay bit-exact throughout
    for round in 0..3 {
        for r in 0..3 {
            let x = input(dim, round * 3 + r);
            let want = oracles[r].infer(&x).unwrap();
            let got = registry
                .submit_rung("mdl", r, x)
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(got, want, "round {round} rung {r}");
        }
    }
    let c = registry.cache_stats();
    assert_eq!(c.misses, 9, "{c:?}");
    assert_eq!(c.recompiles, 6, "{c:?}");
    assert_eq!(c.evictions, 8, "{c:?}");
    // per-rung stats survive eviction
    for i in registry.ladder("mdl").unwrap() {
        assert_eq!(i.stats.requests, 3, "{}", i.label);
    }
    // rung indices out of range are typed errors, not panics
    assert!(registry.submit_rung("mdl", 7, input(dim, 0)).is_err());
    registry.shutdown();
}

#[test]
fn idle_ladder_requests_take_the_most_accurate_rung() {
    let (man, params) = laddered_manifest();
    for slo in [None, Some(Duration::from_secs(1))] {
        let mut c = cfg();
        c.slo = slo;
        let registry = Arc::new(ModelRegistry::new());
        registry
            .register_ladder("mdl", &man, &params,
                             &Mode::BayesianBits, &LADDER, c)
            .unwrap();
        let dim = registry.plan("mdl").unwrap().input_dim;
        for s in 0..4 {
            registry.submit("mdl", input(dim, s)).unwrap().wait()
                .unwrap();
        }
        let info = registry.ladder("mdl").unwrap();
        assert_eq!(info[2].stats.requests, 4, "slo={slo:?}");
        assert_eq!(info[0].stats.requests + info[1].stats.requests, 0,
                   "slo={slo:?}");
        registry.shutdown();
    }
}

#[test]
fn rung_selection_degrades_monotonically_with_queue_depth() {
    // SLO arm: p90s of 100/200/400us against a 500us budget, batch 4
    let slo_pick = |backlog: u64| -> usize {
        let rungs = [
            RungLoad { lat_ns: 100_000, backlog: 0 },
            RungLoad { lat_ns: 200_000, backlog: 0 },
            RungLoad { lat_ns: 400_000, backlog },
        ];
        pick_rung(&rungs, Some(Duration::from_micros(500)), 16, 4)
    };
    // idle: the most accurate rung fits and wins
    assert_eq!(slo_pick(0), 2);
    // deep queue: nothing fits, fall through to the cheapest rung
    assert_eq!(slo_pick(40), 0);
    let mut prev = slo_pick(0);
    for b in 0..48 {
        let now = slo_pick(b);
        assert!(now <= prev,
                "backlog {b} picked rung {now} after {prev}");
        prev = now;
    }

    // no-SLO arm: linear precision shedding against queue_cap
    let shed_pick = |backlog: u64| -> usize {
        let rungs = [
            RungLoad { lat_ns: 0, backlog },
            RungLoad { lat_ns: 0, backlog: 0 },
            RungLoad { lat_ns: 0, backlog: 0 },
        ];
        pick_rung(&rungs, None, 16, 4)
    };
    assert_eq!(shed_pick(0), 2);
    assert_eq!(shed_pick(16), 0);
    let mut prev = shed_pick(0);
    for b in 0..=20 {
        let now = shed_pick(b);
        assert!(now <= prev,
                "backlog {b} picked rung {now} after {prev}");
        prev = now;
    }

    // unmeasured rungs are treated optimistically under an SLO
    let fresh = [RungLoad { lat_ns: 0, backlog: 30 }; 3];
    assert_eq!(pick_rung(&fresh, Some(Duration::from_micros(1)), 16, 4),
               2);
    // degenerate ladders short-circuit
    assert_eq!(pick_rung(&fresh[..1], None, 16, 4), 0);
    assert_eq!(pick_rung(&[], None, 16, 4), 0);
}

#[test]
fn ladder_registration_validates_thresholds_and_plans() {
    let (man, params) = laddered_manifest();
    let registry = ModelRegistry::new();
    // out-of-range thresholds are rejected
    for bad in [&[0.0][..], &[1.0][..], &[-0.5, 0.3][..]] {
        assert!(registry
            .register_ladder("x", &man, &params, &Mode::BayesianBits,
                             bad, cfg())
            .is_err());
    }
    // an empty threshold list is rejected
    assert!(registry
        .register_ladder("x", &man, &params, &Mode::BayesianBits, &[],
                         cfg())
        .is_err());
    // duplicates collapse instead of erroring (same rung twice is
    // meaningless but harmless to request)
    registry
        .register_ladder("x", &man, &params, &Mode::BayesianBits,
                         &[0.5, 0.5, 0.9], cfg())
        .unwrap();
    assert_eq!(registry.ladder("x").unwrap().len(), 2);
    registry.shutdown();
}
