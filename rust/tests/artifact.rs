//! Serialized plan artifact battery: byte-exact round trips, served
//! outputs identical to a fresh lowering, file save/load (verified
//! and not), and a corruption battery — every flipped byte, bad tag,
//! truncation, and structural lie must come back as a typed error,
//! never a panic and never a silently-wrong plan.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;

use bayesian_bits::config::Mode;
use bayesian_bits::engine::artifact::{decode_plan, encode_plan,
                                      FORMAT_VERSION, MAGIC};
use bayesian_bits::engine::{self, load_plan, load_plan_verified,
                            save_plan, synthetic_plan, Engine,
                            EnginePlan};

/// Mirror of the artifact checksum, so tests can re-seal bytes they
/// deliberately patched (a decoder bypassing its own checksum would
/// defeat the corruption battery).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Recompute and overwrite the trailing checksum after a deliberate
/// body patch.
fn reseal(bytes: &mut [u8]) {
    let n = bytes.len();
    let sum = fnv1a(&bytes[..n - 8]);
    bytes[n - 8..].copy_from_slice(&sum.to_le_bytes());
}

fn input(dim: usize, salt: usize) -> Vec<f32> {
    (0..dim).map(|j| ((salt * dim + j) as f32 * 0.37).sin()).collect()
}

/// A spread of plans covering the format surface: packed + f32 rows,
/// pruning, spatial convs with pre-ops, and the legacy flat path.
fn plans() -> Vec<(String, EnginePlan)> {
    let mut out = Vec::new();
    out.push(("synthetic".to_string(),
              synthetic_plan("rt", &[8, 16, 4], 4, 8, 0.2, 9)
                  .unwrap()));
    for (model, legacy) in [("lenet5", false), ("lenet5", true)] {
        let (man, params) = support::preset_manifest(model, legacy);
        let plan = engine::lower_with_mode_at(&man, &params,
                                              &Mode::BayesianBits, 0.5)
            .unwrap();
        out.push((format!("{model}{}", if legacy { "-legacy" }
                                       else { "" }),
                  plan));
    }
    out
}

// ------------------------------------------------------ round trips

/// encode -> decode -> encode is byte-identical, and the decoded plan
/// serves bit-exactly the same outputs as the fresh lowering it came
/// from — the artifact is the plan, not an approximation of it.
#[test]
fn round_trip_is_byte_stable_and_serves_identically() {
    for (label, plan) in plans() {
        let bytes = encode_plan(&plan);
        assert_eq!(&bytes[..MAGIC.len()], MAGIC);
        let decoded = decode_plan(&bytes)
            .unwrap_or_else(|e| panic!("{label}: {e:#}"));
        assert_eq!(encode_plan(&decoded), bytes,
                   "{label}: re-encode must be byte-identical");
        let mut fresh = Engine::new(Arc::new(plan));
        let mut loaded = Engine::new(Arc::new(decoded));
        let dim = fresh.plan().input_dim;
        for salt in 0..3 {
            let x = input(dim, salt);
            assert_eq!(loaded.infer(&x).unwrap(),
                       fresh.infer(&x).unwrap(),
                       "{label}: decoded plan must serve bit-exactly");
        }
    }
}

/// File-level save/load, plus the verified load that compiles both
/// program paths and runs the static verifier on the decoded plan.
#[test]
fn save_then_load_verified_round_trips_on_disk() {
    let plan = synthetic_plan("disk", &[8, 16, 4], 4, 8, 0.2, 9)
        .unwrap();
    let path = std::env::temp_dir().join(format!(
        "bbits_artifact_disk_{}.plan", std::process::id()));
    let n = save_plan(&path, &plan).unwrap();
    assert_eq!(n, std::fs::metadata(&path).unwrap().len() as usize);
    let loaded = load_plan(&path).unwrap();
    let verified = load_plan_verified(&path, None).unwrap();
    let mut fresh = Engine::new(Arc::new(plan));
    let x = input(8, 1);
    let want = fresh.infer(&x).unwrap();
    assert_eq!(Engine::new(Arc::new(loaded)).infer(&x).unwrap(), want);
    assert_eq!(Engine::new(Arc::new(verified)).infer(&x).unwrap(),
               want);
    let _ = std::fs::remove_file(&path);
}

// ------------------------------------------------------- corruption

/// Every single-byte corruption of a valid artifact is a typed error:
/// magic flips report bad magic, body flips fail the checksum, and
/// checksum flips fail the comparison — and none of them panic. A
/// small plan keeps the exhaustive sweep cheap.
#[test]
fn every_single_byte_flip_is_rejected() {
    let plan = synthetic_plan("flip", &[4, 3], 2, 4, 0.0, 3).unwrap();
    let bytes = encode_plan(&plan);
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0xff;
        let err = decode_plan(&bad).expect_err(&format!(
            "flipping byte {i} must not decode"));
        let msg = format!("{err:#}");
        if i < MAGIC.len() {
            assert!(msg.contains("bad magic"), "byte {i}: {msg}");
        } else {
            assert!(msg.contains("checksum"), "byte {i}: {msg}");
        }
    }
}

/// Truncation at any point — including mid-header — is a typed error.
#[test]
fn truncation_is_rejected() {
    let plan = synthetic_plan("trunc", &[4, 3], 2, 4, 0.0, 3).unwrap();
    let bytes = encode_plan(&plan);
    for keep in [0, 1, MAGIC.len(), MAGIC.len() + 4, bytes.len() / 2,
                 bytes.len() - 1]
    {
        assert!(decode_plan(&bytes[..keep]).is_err(),
                "{keep} of {} bytes must not decode", bytes.len());
    }
}

/// An unsupported format version is refused with a message naming
/// both versions (the bytes are re-sealed, so it is the version
/// check, not the checksum, doing the refusing).
#[test]
fn unknown_format_version_is_rejected() {
    let plan = synthetic_plan("ver", &[4, 3], 2, 4, 0.0, 3).unwrap();
    let mut bytes = encode_plan(&plan);
    let off = MAGIC.len();
    bytes[off..off + 4]
        .copy_from_slice(&(FORMAT_VERSION + 1).to_le_bytes());
    reseal(&mut bytes);
    let err = decode_plan(&bytes).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains(&format!("version {}", FORMAT_VERSION + 1))
                && msg.contains("not"),
            "{msg}");
}

/// A structurally inconsistent plan (here: a kept-channel table that
/// disagrees with its packed rows) survives the byte layer but is
/// caught by the re-validation decode runs on every artifact — the
/// decoder trusts nothing the checksum alone would bless.
#[test]
fn structural_lies_fail_revalidation() {
    let plan = synthetic_plan("lie", &[8, 16, 4], 4, 8, 0.0, 7)
        .unwrap();
    let mut broken = plan.clone();
    broken.layers[0].kept.pop();
    let mut bytes = encode_plan(&broken);
    reseal(&mut bytes);
    let err = decode_plan(&bytes).unwrap_err();
    assert!(format!("{err:#}").contains("validation"), "{err:#}");
}

/// Corrupting packed weight words so a code field leaves its grid
/// range is caught by `PackedMatrix::from_raw` during decode, before
/// anything could execute the bogus codes.
#[test]
fn out_of_range_packed_codes_fail_decode() {
    let plan = synthetic_plan("codes", &[8, 16, 4], 2, 8, 0.0, 7)
        .unwrap();
    let mut bytes = encode_plan(&plan);
    // the first packed word follows: magic, version, model str,
    // 3 u64 dims, then layer 0's name str, 2 u64 dims, u32 w_bits,
    // kept u32s, packed flag + header. Rather than chase offsets,
    // patch every 8-byte window until one decodes to the typed
    // packed-matrix error — and require that it exists.
    let mut saw_packed_error = false;
    let step = 8;
    let mut i = MAGIC.len() + 4;
    while i + step < bytes.len() - 8 {
        let mut bad = bytes.clone();
        for b in &mut bad[i..i + step] {
            *b = 0xff;
        }
        reseal(&mut bad);
        match decode_plan(&bad) {
            Ok(_) => {}
            Err(e) => {
                if format!("{e:#}").contains("packed matrix") {
                    saw_packed_error = true;
                    break;
                }
            }
        }
        i += step;
    }
    assert!(saw_packed_error,
            "no 8-byte stomp produced the typed packed-matrix error");
    // keep the borrow checker honest about the original buffer
    let _ = &mut bytes;
}
