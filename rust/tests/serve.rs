//! Serving lifecycle edges: config validation, ticket drops, and
//! shutdown draining. The happy-path serving behavior lives in the
//! `engine::serve` unit tests and `tests/golden_e2e.rs`; this suite
//! pins the ways a server can be *mis*used without wedging a worker
//! or losing a queued request.

use std::sync::Arc;
use std::time::Duration;

use bayesian_bits::engine::serve::{bounded_draw, ServeConfig,
                                   ServeConfigError, Server};
use bayesian_bits::engine::{synthetic_plan, Engine, EnginePlan};

fn tiny_plan() -> Arc<EnginePlan> {
    Arc::new(synthetic_plan("t", &[8, 16, 4], 4, 8, 0.2, 9).unwrap())
}

#[test]
fn config_zero_fields_are_typed_errors_not_hangs() {
    let ok = ServeConfig::default();
    assert_eq!(ok.validate(), Ok(()));
    let cases = [
        (ServeConfig { workers: 0, ..ok.clone() },
         ServeConfigError::ZeroWorkers),
        (ServeConfig { queue_cap: 0, ..ok.clone() },
         ServeConfigError::ZeroQueueCap),
        (ServeConfig { max_batch: 0, ..ok.clone() },
         ServeConfigError::ZeroMaxBatch),
        (ServeConfig { deadline: Duration::ZERO, ..ok.clone() },
         ServeConfigError::ZeroDeadline),
        (ServeConfig { slo: Some(Duration::ZERO), ..ok.clone() },
         ServeConfigError::ZeroSlo),
    ];
    for (cfg, want) in cases {
        assert_eq!(cfg.validate(), Err(want), "{cfg:?}");
        // Server::start rejects the same configs up front — the error
        // is the typed one, stringified through anyhow
        let err = Server::start(tiny_plan(), cfg).unwrap_err();
        assert!(format!("{err}").contains("serve config"), "{err}");
    }
}

#[test]
fn dropped_tickets_do_not_wedge_workers() {
    let plan = tiny_plan();
    let server = Server::start(
        plan.clone(),
        ServeConfig {
            workers: 1,
            queue_cap: 16,
            max_batch: 4,
            deadline: Duration::from_micros(200),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    // submit a burst and immediately drop every ticket: the response
    // sends fail silently and the worker must keep going
    for i in 0..8 {
        let x: Vec<f32> =
            (0..8).map(|j| ((i * 8 + j) as f32).cos()).collect();
        drop(server.submit(x).unwrap());
    }
    // a later request on the same (single) worker still answers, and
    // bit-exactly
    let x: Vec<f32> = (0..8).map(|j| (j as f32).sin()).collect();
    let want = Engine::new(plan).infer(&x).unwrap();
    let got = server.submit(x).unwrap().wait().unwrap();
    assert_eq!(got, want);
    let stats = server.shutdown();
    // every request — including the abandoned ones — was processed
    assert_eq!(stats.requests, 9);
    assert_eq!(stats.errors, 0);
}

#[test]
fn shutdown_drains_queued_requests_deterministically() {
    let plan = tiny_plan();
    let server = Server::start(
        plan.clone(),
        ServeConfig {
            workers: 1,
            queue_cap: 64,
            max_batch: 2,
            deadline: Duration::from_micros(100),
            ..ServeConfig::default()
        },
    )
    .unwrap();
    let mut eng = Engine::new(plan);
    let mut tickets = Vec::new();
    let mut want = Vec::new();
    for i in 0..20 {
        let x: Vec<f32> =
            (0..8).map(|j| ((i * 8 + j) as f32 * 0.13).sin()).collect();
        want.push(eng.infer(&x).unwrap());
        tickets.push(server.submit(x).unwrap());
    }
    // shutdown with (very likely) queued work: it must block until
    // the single worker has drained the queue, so by the time it
    // returns EVERY ticket already has its answer — none dangle
    let stats = server.shutdown();
    assert_eq!(stats.requests, 20);
    assert_eq!(stats.errors, 0);
    for (t, w) in tickets.into_iter().zip(&want) {
        assert_eq!(&t.wait().unwrap(), w, "ticket answered post-drain");
    }
}

#[test]
fn submitting_after_shutdown_errors_cleanly() {
    let plan = tiny_plan();
    let server =
        Server::start(plan.clone(), ServeConfig::default()).unwrap();
    // exercise one request so the pool actually spins up
    let x: Vec<f32> = vec![0.5; 8];
    server.submit(x.clone()).unwrap().wait().unwrap();
    let registry = server.registry().clone();
    let id = plan.model.clone();
    server.shutdown();
    // the registry behind the (consumed) server refuses new work
    // instead of hanging on a dead pool
    let err = registry.submit(&id, x).unwrap_err();
    assert!(format!("{err}").contains("shut down"), "{err}");
}

#[test]
fn bounded_draw_replaces_modulo_without_bias_artifacts() {
    // range correctness at the extremes
    assert_eq!(bounded_draw(0, 10), 0);
    assert_eq!(bounded_draw(u64::MAX, 10), 9);
    assert_eq!(bounded_draw(u64::MAX / 2, 2), 0);
    assert_eq!(bounded_draw(u64::MAX / 2 + 2, 2), 1);
    // distribution sanity over an LCG stream for a non-power-of-two
    // bound: every bucket within 5% of uniform
    let n = 7u64;
    let draws = 350_000u64;
    let mut x = 0x853C49E6748FEA9Bu64;
    let mut counts = vec![0u64; n as usize];
    for _ in 0..draws {
        x = x
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        let j = bounded_draw(x, n);
        assert!(j < n);
        counts[j as usize] += 1;
    }
    let expect = (draws / n) as i64;
    for (b, c) in counts.iter().enumerate() {
        let dev = (*c as i64 - expect).abs();
        assert!(dev < expect / 20,
                "bucket {b}: {c} vs ~{expect} (dev {dev})");
    }
}
