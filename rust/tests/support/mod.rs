//! Shared integration-test support: build a full Bayesian-Bits
//! manifest (params + quantizers + layer table, spatial fields
//! included) from each Rust model-preset descriptor — the same shapes
//! the python exporter emits. Used by `tests/conv_parity.rs` (spatial
//! lowering battery) and `tests/ir.rs` (execution-graph invariants).
//!
//! Included per-test-crate via `#[path = "support/mod.rs"]`, so keep
//! everything here used by every includer or justify `allow(dead_code)`
//! at the item.

use std::path::Path;

use bayesian_bits::models::{descriptor, Preset};
use bayesian_bits::rng::Pcg64;
use bayesian_bits::runtime::Manifest;
use bayesian_bits::util::json::Json;

pub struct ManifestBuilder {
    params_json: Vec<String>,
    quant_json: Vec<String>,
    layers_json: Vec<String>,
    params: Vec<f32>,
    slot_offset: usize,
    rng: Pcg64,
}

impl ManifestBuilder {
    fn new(seed: u64) -> Self {
        Self {
            params_json: Vec::new(),
            quant_json: Vec::new(),
            layers_json: Vec::new(),
            params: Vec::new(),
            slot_offset: 0,
            rng: Pcg64::new(seed),
        }
    }

    fn param(&mut self, name: &str, shape: &[usize], group: char,
             values: Vec<f32>) {
        let size: usize = shape.iter().product();
        assert_eq!(values.len(), size, "{name}");
        let shape_s: Vec<String> =
            shape.iter().map(|d| d.to_string()).collect();
        self.params_json.push(format!(
            "{{\"name\":\"{name}\",\"shape\":[{}],\"group\":\"{group}\",\
             \"offset\":{},\"size\":{size}}}",
            shape_s.join(","),
            self.params.len()
        ));
        self.params.extend(values);
    }

    fn quantizer(&mut self, name: &str, kind: char, signed: bool,
                 channels: usize, macs: u64) {
        let n_slots = channels + 4;
        self.quant_json.push(format!(
            "{{\"name\":\"{name}\",\"kind\":\"{kind}\",\
             \"signed\":{signed},\"channels\":{channels},\
             \"levels\":[2,4,8,16,32],\"offset\":{},\
             \"n_slots\":{n_slots},\"consumer_macs\":{macs}}}",
            self.slot_offset
        ));
        self.slot_offset += n_slots;
        // phi: channel slots open, chain -> 8 bit (z4, z8 open)
        let mut phi = vec![6.0f32; channels];
        phi.extend_from_slice(&[6.0, 6.0, -6.0, -6.0]);
        self.param(&format!("{name}.phi"), &[n_slots], 'g', phi);
        let beta = if kind == 'w' { 1.0 } else { 2.0 };
        self.param(&format!("{name}.beta"), &[1], 's', vec![beta]);
    }

    fn normals(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal() * scale).collect()
    }
}

/// Build a full manifest + parameter vector for one model preset.
/// `legacy` emits the pre-spatial schema (no `ksize`/.../`pre` layer
/// fields), as a pre-schema exporter would have written it.
pub fn preset_manifest(model: &str, legacy: bool) -> (Manifest, Vec<f32>) {
    let desc = descriptor(model, Preset::Small).unwrap();
    let input = match model {
        "lenet5" => (16usize, 16usize, 1usize),
        "vgg7" => (16, 16, 3),
        _ => (24, 24, 3),
    };
    let classes = desc.last().unwrap().cout;
    let mut b = ManifestBuilder::new(42);
    for l in &desc {
        if l.act_q == format!("{}.in", l.name) {
            b.quantizer(&l.act_q, 'a', false, 1, l.macs);
        }
        let (wshape, fan) = match &l.conv {
            Some(m) => {
                let cg = l.cin / m.groups;
                (vec![m.ksize, m.ksize, cg, l.cout],
                 m.ksize * m.ksize * cg)
            }
            None => (vec![l.cin, l.cout], l.cin),
        };
        let scale = (2.0 / fan as f32).sqrt();
        let w = b.normals(fan * l.cout, scale);
        b.param(&format!("{}.w", l.name), &wshape, 'w', w);
        b.quantizer(&l.weight_q, 'w', true, l.cout, l.macs);
        let bias = b.normals(l.cout, 0.05);
        b.param(&format!("{}.b", l.name), &[l.cout], 'w', bias);
    }
    for l in &desc {
        let spatial = match &l.conv {
            Some(m) if !legacy => format!(
                ",\"ksize\":{},\"stride\":{},\"padding\":\"{}\",\
                 \"groups\":{},\"in_h\":{},\"in_w\":{}",
                m.ksize, m.stride, m.padding.label(), m.groups, m.in_h,
                m.in_w),
            _ => String::new(),
        };
        let pre = if legacy || l.pre_ops.is_empty() {
            String::new()
        } else {
            let ops: Vec<String> =
                l.pre_ops.iter().map(|o| format!("\"{o}\"")).collect();
            format!(",\"pre\":[{}]", ops.join(","))
        };
        b.layers_json.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"{}\",\"macs\":{},\
             \"cin\":{},\"cout\":{},\"weight_q\":\"{}\",\
             \"act_q\":\"{}\",\"residual_input\":{}{spatial}{pre}}}",
            l.name, l.kind, l.macs, l.cin, l.cout, l.weight_q, l.act_q,
            l.residual_input));
    }
    let lam: Vec<String> =
        (0..b.slot_offset).map(|_| "1".to_string()).collect();
    let text = format!(
        "{{\"name\":\"{model}\",\"engine\":\"bb\",\"preset\":\"small\",\
         \"batch\":4,\"n_params\":{},\"n_slots\":{},\
         \"input_shape\":[{},{},{}],\"num_classes\":{classes},\
         \"dataset\":{{\"name\":\"mnist_like\",\"input\":[{},{},{}],\
         \"classes\":{classes},\"train\":8,\"test\":4}},\
         \"params\":[{}],\"quantizers\":[{}],\"layers\":[{}],\
         \"lam_base\":[{}],\"hlo_train\":\"t.hlo.txt\",\
         \"hlo_eval\":\"e.hlo.txt\",\"init_file\":\"i.bin\"}}",
        b.params.len(),
        b.slot_offset,
        input.0, input.1, input.2,
        input.0, input.1, input.2,
        b.params_json.join(","),
        b.quant_json.join(","),
        b.layers_json.join(","),
        lam.join(","));
    let man =
        Manifest::from_json(&Json::parse(&text).unwrap(), Path::new("/tmp"))
            .unwrap();
    (man, b.params)
}
