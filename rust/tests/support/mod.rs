//! Shared integration-test support: build a full Bayesian-Bits
//! manifest (params + quantizers + layer table, spatial fields
//! included) from each Rust model-preset descriptor — the same shapes
//! the python exporter emits. The builder itself moved into the
//! library (`runtime::manifest_gen`) so the serving CLI can register
//! preset models; this module keeps the historical test-facing
//! signature. Used by `tests/conv_parity.rs` (spatial lowering
//! battery), `tests/ir.rs` (execution-graph invariants), and
//! `tests/serve_multi.rs` (registry/router battery).
//!
//! Included per-test-crate via `#[path = "support/mod.rs"]`, so keep
//! everything here used by every includer or justify `allow(dead_code)`
//! at the item.

use bayesian_bits::runtime::Manifest;

/// Build a full manifest + parameter vector for one model preset.
/// `legacy` emits the pre-spatial schema (no `ksize`/.../`pre` layer
/// fields), as a pre-schema exporter would have written it.
pub fn preset_manifest(model: &str, legacy: bool) -> (Manifest, Vec<f32>) {
    bayesian_bits::runtime::manifest_gen::preset_manifest(model, legacy,
                                                          42)
        .unwrap()
}
