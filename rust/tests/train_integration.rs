//! Integration tests over the full training stack (artifacts required).
//!
//! These drive the real Trainer on the real PJRT runtime with reduced
//! step budgets: learning happens, modes produce the configurations
//! they promise, fixed baselines land on the paper's exact BOP
//! percentages, and checkpoints round-trip.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bayesian_bits::config::{Mode, RunConfig};
use bayesian_bits::coordinator::gate_manager::GateManager;
use bayesian_bits::coordinator::ptq;
use bayesian_bits::coordinator::trainer::Trainer;
use bayesian_bits::runtime::{Manifest, Runtime, TrainState};

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// AOT artifacts are an optional build product; these tests self-skip
/// without them.
fn artifacts_built() -> bool {
    let ok = artifacts_dir().join("lenet5_manifest.json").exists();
    if !ok {
        eprintln!("skipping: AOT artifacts not built \
                   (run `make artifacts`)");
    }
    ok
}

/// Most tests here additionally drive the real PJRT runtime — absent
/// in builds linked against the vendored `xla` stub.
fn runtime_ready() -> bool {
    if !artifacts_built() {
        return false;
    }
    match Runtime::cpu() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            false
        }
    }
}

fn quick_cfg(model: &str, mode: Mode, mu: f64, steps: usize)
             -> RunConfig {
    RunConfig {
        model: model.into(),
        mode,
        mu,
        steps,
        finetune_steps: steps / 4,
        lr_w: 1e-3,
        lr_g: 3e-2,
        lr_s: 1e-3,
        eval_every: 0,
        seed: 1,
        deterministic_gates: false,
        artifacts_dir: artifacts_dir().to_string_lossy().into_owned(),
        out_dir: std::env::temp_dir().join("bbits_it")
            .to_string_lossy().into_owned(),
    }
}

fn runtime() -> Arc<Runtime> {
    Arc::new(Runtime::cpu().unwrap())
}

#[test]
fn bb_training_learns_and_compresses() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    // phi travels from +6 to the -0.94 threshold (Eq. 22); with Adam at
    // lr_g = 3e-2 that takes ~250 steps, so give it 320.
    let cfg = quick_cfg("lenet5", Mode::BayesianBits, 0.01, 320);
    let mut trainer = Trainer::new(rt, man, cfg).unwrap();
    let r = trainer.run().unwrap();
    assert!(r.accuracy > 0.8, "accuracy {} too low", r.accuracy);
    assert!(r.rel_bops_pct < 50.0,
            "no compression learned: {}%", r.rel_bops_pct);
    assert!(r.history.steps.len() >= 200);
    // loss decreased
    let first = r.history.steps[..10].iter()
        .map(|s| s.loss as f64).sum::<f64>() / 10.0;
    assert!(r.history.smoothed_loss(10) < first * 0.5);
}

#[test]
fn fixed_mode_hits_paper_bops_exactly() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    for ((w, a), want_pct) in
        [((8u32, 8u32), 6.25), ((4, 4), 1.5625), ((2, 2), 0.390625)]
    {
        let cfg = quick_cfg("lenet5",
                            Mode::Fixed { w_bits: w, a_bits: a }, 0.0, 20);
        let mut trainer = Trainer::new(rt.clone(), man.clone(), cfg)
            .unwrap();
        let r = trainer.run().unwrap();
        assert!((r.rel_bops_pct - want_pct).abs() < 1e-6,
                "w{w}a{a}: {} vs {want_pct}", r.rel_bops_pct);
    }
}

#[test]
fn quant_only_mode_never_prunes() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    let cfg = quick_cfg("lenet5", Mode::QuantOnly, 0.1, 80);
    let mut trainer = Trainer::new(rt, man, cfg).unwrap();
    let r = trainer.run().unwrap();
    for (name, st) in &r.states {
        assert!(st.keep_ratio == 1.0, "{name} pruned in quant-only mode");
        assert!(st.bits >= 2, "{name} fully pruned in quant-only mode");
    }
}

#[test]
fn prune_only_mode_keeps_fixed_bits() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    let cfg = quick_cfg(
        "lenet5", Mode::PruneOnly { w_bits: 4, a_bits: 8 }, 0.5, 80);
    let mut trainer = Trainer::new(rt, man.clone(), cfg).unwrap();
    let r = trainer.run().unwrap();
    for q in &man.quantizers {
        let st = &r.states[&q.name];
        if q.kind == 'a' {
            assert_eq!(st.bits, 8, "{}", q.name);
        } else if st.keep_ratio > 0.0 {
            assert_eq!(st.bits, 4, "{}", q.name);
        }
    }
}

#[test]
fn deterministic_gates_run_end_to_end() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    let mut cfg = quick_cfg("lenet5", Mode::BayesianBits, 0.01, 40);
    cfg.deterministic_gates = true;
    cfg.lr_g /= 10.0;
    let mut trainer = Trainer::new(rt, man, cfg).unwrap();
    let r = trainer.run().unwrap();
    assert!(r.deterministic);
    assert!(r.accuracy.is_finite());
}

#[test]
fn dq_baseline_trains_and_reports_bits() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5_dq").unwrap();
    let cfg = quick_cfg("lenet5_dq", Mode::Dq, 0.05, 120);
    let mut trainer = Trainer::new(rt, man, cfg).unwrap();
    let r = trainer.run().unwrap();
    assert!(r.accuracy > 0.5, "dq accuracy {}", r.accuracy);
    // inferred bits live in the gate snapshots (one slot per quantizer)
    let last = r.history.gate_snapshots.last().unwrap();
    assert!(last.probs.iter().all(|b| (1.0..=32.0).contains(b)));
    // regularizer should push bits below the 8-bit init on average
    let mean: f32 =
        last.probs.iter().sum::<f32>() / last.probs.len() as f32;
    assert!(mean < 8.5, "mean bits {mean}");
}

#[test]
fn ptq_pretrain_cache_and_learn() {
    if !runtime_ready() {
        return;
    }
    let rt = runtime();
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    let mut base_cfg = quick_cfg("lenet5", Mode::Fp32, 0.0, 150);
    base_cfg.finetune_steps = 0;
    let dir = std::env::temp_dir().join("bbits_it_ptq");
    let _ = std::fs::remove_dir_all(&dir);
    let ckpt = dir.join("base.ckpt");
    let base =
        ptq::pretrain_or_load(rt.clone(), &man, &base_cfg, &ckpt)
            .unwrap();
    assert!(ckpt.exists());
    // second call loads from cache (same params)
    let base2 =
        ptq::pretrain_or_load(rt.clone(), &man, &base_cfg, &ckpt)
            .unwrap();
    assert_eq!(base.params, base2.params);

    let p = ptq::ptq_learn(rt.clone(), &man, &base, 0.02, true, 300, 1,
                           5e-2).unwrap();
    assert!(p.accuracy > 0.5, "ptq accuracy {}", p.accuracy);
    assert!(p.rel_bops_pct < 100.0);

    let fixed = ptq::fixed_point(rt, &man, &base, 8, 8).unwrap();
    assert!((fixed.rel_bops_pct - 6.25).abs() < 1e-6);
}

#[test]
fn gate_manager_locks_cover_all_slots() {
    if !artifacts_built() {
        return;
    }
    let man = Manifest::load(&artifacts_dir(), "resnet18").unwrap();
    let gm = GateManager::new(&man);
    for mode in [
        Mode::Fp32,
        Mode::Fixed { w_bits: 4, a_bits: 8 },
        Mode::QuantOnly,
        Mode::PruneOnly { w_bits: 4, a_bits: 8 },
        Mode::BayesianBits,
    ] {
        let (mask, val) = gm.locks(&mode);
        assert_eq!(mask.len(), man.n_slots);
        assert!(mask.iter().all(|m| *m == 0.0 || *m == 1.0));
        assert!(val.iter().all(|v| *v == 0.0 || *v == 1.0));
        // test-time gates under full locks equal the lock values
        if matches!(mode, Mode::Fp32 | Mode::Fixed { .. }) {
            let phi = vec![0.0f64; man.n_slots];
            let z = gm.test_gates(&phi, &mask, &val);
            assert_eq!(z, val);
        }
    }
}

#[test]
fn frozen_state_restores_from_checkpoint() {
    if !artifacts_built() {
        return;
    }
    use bayesian_bits::coordinator::checkpoint;
    let man = Manifest::load(&artifacts_dir(), "lenet5").unwrap();
    let state = TrainState::init(&man).unwrap();
    let dir = std::env::temp_dir().join("bbits_it_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("x.ckpt");
    checkpoint::save(&p, &man.name, &state).unwrap();
    let (name, got) = checkpoint::load(&p).unwrap();
    assert_eq!(name, man.name);
    assert_eq!(got.params, state.params);
}
