//! Spatial conv parity battery: the integer im2col datapath vs a
//! naive f32 spatial reference (independent indexing, no shared
//! kernel code), across the 2/4/8/16 x 4/8/16 width grid, stride 1/2,
//! SAME/VALID padding, and depthwise groups — with pruned output
//! channels elided. Also proves the model-preset descriptor tables
//! lower their conv/dwconv layers onto the spatial datapath end to
//! end.
//!
//! Pure host subsystem: always runs; CI additionally runs it in
//! `--release` (the full width grid is integer-kernel heavy in debug).

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;

use bayesian_bits::engine::{lower, synthetic_conv_plan, ActSpec,
                            Engine, EnginePlan, PreOp};
use bayesian_bits::models::Padding;
use bayesian_bits::quant::grid::quantize_codes_host;
use bayesian_bits::rng::Pcg64;
use support::preset_manifest;

/// Naive f32 spatial convolution over the plan's simulated-quant
/// weights and activation grid — direct nested loops, indexing derived
/// from first principles rather than the engine's patch extractor.
fn naive_reference(plan: &EnginePlan, x: &[f32]) -> Vec<f32> {
    let l = &plan.layers[0];
    let sp = l.spatial.as_ref().expect("reference needs a spatial layer");
    let deq: Vec<f32> = match l.act {
        ActSpec::F32 => x.to_vec(),
        ActSpec::Int { bits, beta, signed } => {
            let (s, codes) = quantize_codes_host(x, beta, bits, signed);
            codes.iter().map(|q| s * *q as f32).collect()
        }
    };
    let (k, stride) = (sp.k, sp.stride);
    let cg = sp.in_c / sp.groups;
    let cpg = l.out_dim / sp.groups;
    let plen = l.in_dim;
    let mut out = vec![0.0f32; sp.out_pixels() * l.out_dim];
    if let Some(b) = &l.bias {
        for p in 0..sp.out_pixels() {
            out[p * l.out_dim..(p + 1) * l.out_dim]
                .copy_from_slice(b);
        }
    }
    for (r, ch) in l.kept.iter().enumerate() {
        let g = *ch as usize / cpg;
        for oh in 0..sp.out_h {
            for ow in 0..sp.out_w {
                let mut acc = 0.0f32;
                for kh in 0..k {
                    for kw in 0..k {
                        let ih = (oh * stride + kh) as isize
                            - sp.pad_top as isize;
                        let iw = (ow * stride + kw) as isize
                            - sp.pad_left as isize;
                        if ih < 0
                            || iw < 0
                            || ih as usize >= sp.in_h
                            || iw as usize >= sp.in_w
                        {
                            continue; // zero padding
                        }
                        for ci in 0..cg {
                            let wv = l.f32_rows
                                [r * plen + (kh * k + kw) * cg + ci];
                            let av = deq[(ih as usize * sp.in_w
                                + iw as usize)
                                * sp.in_c
                                + g * cg
                                + ci];
                            acc += wv * av;
                        }
                    }
                }
                out[(oh * sp.out_w + ow) * l.out_dim + *ch as usize] +=
                    acc;
            }
        }
    }
    out
}

/// Run `trials` random inputs through the plan; the integer path and
/// the engine's f32 fallback must both sit within
/// `1e-4 * (1 + |y|)` of the naive reference, and pruned channels
/// must answer exactly their bias at every pixel.
fn check_case(plan: EnginePlan, label: &str, trials: usize, seed: u64) {
    let l0 = &plan.layers[0];
    let pruned: Vec<usize> = (0..l0.out_dim)
        .filter(|c| !l0.kept.contains(&(*c as u32)))
        .collect();
    let bias = l0.bias.clone();
    let out_dim = l0.out_dim;
    let opix = l0.spatial.as_ref().unwrap().out_pixels();
    let plan = Arc::new(plan);
    let mut eng = Engine::new(plan.clone());
    let mut rng = Pcg64::new(seed);
    for t in 0..trials {
        let x: Vec<f32> = (0..plan.input_dim)
            .map(|_| rng.normal() * 1.2)
            .collect();
        let want = naive_reference(&plan, &x);
        let got = eng.infer(&x).unwrap();
        assert_eq!(got.len(), want.len(), "{label}");
        let reference = eng.infer_reference(&x).unwrap();
        for (i, w) in want.iter().enumerate() {
            let tol = 1e-4 * (1.0 + w.abs());
            assert!((got[i] - w).abs() <= tol,
                    "{label} t={t} [{i}]: int {} vs naive {w}", got[i]);
            assert!((reference[i] - w).abs() <= tol,
                    "{label} t={t} [{i}]: f32 {} vs naive {w}",
                    reference[i]);
        }
        // pruned-output-channel elision: exactly the bias, every pixel
        for c in &pruned {
            let b = bias.as_ref().map(|b| b[*c]).unwrap_or(0.0);
            for p in 0..opix {
                assert_eq!(got[p * out_dim + c], b,
                           "{label}: pruned channel {c} pixel {p}");
            }
        }
    }
}

#[test]
fn conv_parity_across_width_stride_padding_grid() {
    let mut seed = 100;
    for &w_bits in &[2u32, 4, 8, 16] {
        for &a_bits in &[4u32, 8, 16] {
            for &stride in &[1usize, 2] {
                for padding in [Padding::Same, Padding::Valid] {
                    seed += 1;
                    let label = format!(
                        "conv w{w_bits}a{a_bits} s{stride} {}",
                        padding.label());
                    let plan = synthetic_conv_plan(
                        &label, 7, 3, 6, 3, stride, padding, 1, w_bits,
                        a_bits, 0.34, seed)
                        .unwrap();
                    assert!(plan.layers[0].packed.is_some()
                            || plan.layers[0].w_bits >= 32);
                    check_case(plan, &label, 2, seed * 7 + 1);
                }
            }
        }
    }
}

#[test]
fn dwconv_parity_across_width_stride_padding_grid() {
    let mut seed = 900;
    for &w_bits in &[2u32, 4, 8, 16] {
        for &a_bits in &[4u32, 8, 16] {
            for &stride in &[1usize, 2] {
                for padding in [Padding::Same, Padding::Valid] {
                    seed += 1;
                    let label = format!(
                        "dwconv w{w_bits}a{a_bits} s{stride} {}",
                        padding.label());
                    let plan = synthetic_conv_plan(
                        &label, 7, 6, 6, 3, stride, padding, 6, w_bits,
                        a_bits, 0.3, seed)
                        .unwrap();
                    check_case(plan, &label, 2, seed * 11 + 3);
                }
            }
        }
    }
}

#[test]
fn grouped_conv_parity() {
    // 2 groups, 3 channels per group in, 3 out per group
    for (stride, padding) in
        [(1usize, Padding::Same), (2, Padding::Valid)]
    {
        let label = format!("gconv s{stride} {}", padding.label());
        let plan = synthetic_conv_plan(&label, 6, 6, 6, 3, stride,
                                       padding, 2, 4, 8, 0.25, 77)
            .unwrap();
        check_case(plan, &label, 2, 78);
    }
}

#[test]
fn blocked_backend_conv_parity_grid_across_intra_threads() {
    // The blocked panel backend, sharded across 2..4 intra-request
    // threads, must be bit-identical to the forced-scalar oracle on
    // a width/stride/grouping grid — exact integer sums cannot move
    // with panel, tile, or shard order (associativity), so this is an
    // equality assert, not a tolerance check. Covers standard,
    // grouped, and depthwise (groups == cin) conv layers.
    use bayesian_bits::engine::Backend;
    let mut seed = 4000u64;
    for &(groups, cin, cout) in
        &[(1usize, 3usize, 6usize), (2, 6, 6), (6, 6, 6)]
    {
        for &w_bits in &[2u32, 4, 8, 16] {
            for &stride in &[1usize, 2] {
                seed += 1;
                let padding = if seed % 2 == 0 {
                    Padding::Same
                } else {
                    Padding::Valid
                };
                let label = format!(
                    "blocked g{groups} w{w_bits} s{stride} {}",
                    padding.label());
                let plan = Arc::new(synthetic_conv_plan(
                    &label, 7, cin, cout, 3, stride, padding, groups,
                    w_bits, 8, 0.3, seed)
                    .unwrap());
                let mut scalar = Engine::with_backend(
                    plan.clone(), Some(Backend::Scalar));
                let mut blocked = Engine::with_backend(
                    plan.clone(), Some(Backend::Blocked));
                let mut rng = Pcg64::new(seed * 3 + 1);
                let x: Vec<f32> = (0..plan.input_dim)
                    .map(|_| rng.normal() * 1.2)
                    .collect();
                let want = scalar.infer(&x).unwrap();
                for threads in 2..=4 {
                    blocked.set_intra_threads(threads);
                    let got = blocked.infer(&x).unwrap();
                    assert_eq!(want, got, "{label} intra={threads}");
                }
            }
        }
    }
}

#[test]
fn fully_pruned_conv_layer_answers_bias_per_pixel() {
    // prune probability 1.0 leaves a single surviving channel by
    // construction; force full pruning via the layer's z2 instead
    let plan = synthetic_conv_plan("p", 5, 2, 3, 3, 1, Padding::Same, 1,
                                   4, 8, 0.0, 3)
        .unwrap();
    let l = &plan.layers[0];
    let z2 = vec![0.0f32; l.out_dim];
    let sp = l.spatial.clone().unwrap();
    let w = vec![0.5f32; l.out_dim * l.in_dim];
    let layer = lower::build_conv_layer(
        "p", &w, sp, l.out_dim, &z2, 4, 1.0,
        ActSpec::Int { bits: 8, beta: 2.0, signed: true },
        Some(vec![0.25, -1.5, 2.0]), false, PreOp::Direct)
        .unwrap();
    assert!(layer.kept.is_empty());
    let plan = EnginePlan {
        model: "p".into(),
        input_dim: 5 * 5 * 2,
        output_dim: layer.output_len(),
        layers: vec![layer],
    };
    plan.validate().unwrap();
    let mut eng = Engine::new(Arc::new(plan));
    let y = eng.infer(&vec![1.0f32; 50]).unwrap();
    for p in 0..25 {
        assert_eq!(&y[p * 3..(p + 1) * 3], &[0.25, -1.5, 2.0]);
    }
}

// ---------------------------------------------------------------------
// Model presets: build a full Bayesian-Bits manifest from each Rust
// descriptor table (support::preset_manifest — the same shapes the
// python exporter emits, spatial fields included), lower it, and
// check every conv/dwconv layer landed on the spatial datapath with
// the expected inter-layer ops.
// ---------------------------------------------------------------------

#[test]
fn model_preset_conv_layers_lower_onto_spatial_path() {
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let (man, params) = preset_manifest(model, false);
        let plan = lower::lower(&man, &params).unwrap();
        assert_eq!(plan.layers.len(), man.layers.len(), "{model}");
        for (pl, ml) in plan.layers.iter().zip(&man.layers) {
            if ml.kind == "dense" {
                assert!(pl.spatial.is_none(), "{model}/{}", pl.name);
            } else {
                // the tentpole invariant: every conv/dwconv preset
                // layer executes on the spatial integer datapath
                let sp = pl.spatial.as_ref().unwrap_or_else(|| {
                    panic!("{model}/{}: not spatial", pl.name)
                });
                assert_eq!(pl.in_dim, sp.patch_len(), "{model}");
                assert_eq!(pl.w_bits, 8, "{model}/{}", pl.name);
                assert!(pl.packed.is_some(), "{model}/{}", pl.name);
                // non-branch layers never need the shape bridge
                if !pl.name.ends_with(".ds") {
                    assert!(!matches!(pl.pre,
                                      PreOp::AdaptSpatial { .. }),
                            "{model}/{}: {:?}", pl.name, pl.pre);
                }
            }
        }
        // the recorded train-graph ops were replayed
        match model {
            "lenet5" => {
                assert_eq!(plan.layers[1].pre,
                           PreOp::MaxPool2 { h: 16, w: 16, c: 8 });
                // maxpool2 + flatten head, from the manifest `pre`
                assert_eq!(plan.layers[2].pre,
                           PreOp::MaxPool2 { h: 8, w: 8, c: 16 });
            }
            "vgg7" => {
                assert!(matches!(plan.layers[2].pre,
                                 PreOp::MaxPool2 { .. }));
            }
            "resnet18" => {
                let ds = plan
                    .layers
                    .iter()
                    .find(|l| l.name == "s2b1.ds")
                    .unwrap();
                assert!(matches!(ds.pre, PreOp::AdaptSpatial { .. }));
            }
            _ => {
                let fc = plan.layers.last().unwrap();
                assert!(matches!(fc.pre,
                                 PreOp::GlobalAvgPool { .. }));
            }
        }
        // end to end: an image-shaped batch flows through the plan
        let mut eng = Engine::new(Arc::new(plan));
        let mut rng = Pcg64::new(7);
        let n = 2;
        let xs: Vec<f32> = (0..n * man.input_shape.iter()
            .product::<usize>())
            .map(|_| rng.normal())
            .collect();
        let y = eng.infer_batch(&xs, n).unwrap();
        assert_eq!(y.len(), n * man.num_classes, "{model}");
        assert!(y.iter().all(|v| v.is_finite()), "{model}");
    }
}

#[test]
fn legacy_manifest_without_spatial_fields_still_loads_and_serves() {
    // backward compatibility: the same model written by a pre-spatial
    // exporter (no ksize/stride/padding/groups/in_h/in_w/pre fields)
    // lowers onto the legacy flattened-GEMM path and still serves
    let (man, params) = preset_manifest("lenet5", true);
    assert!(man.layers.iter().all(|l| l.conv.is_none()));
    assert!(man.layers.iter().all(|l| l.pre_ops.is_empty()));
    let plan = lower::lower(&man, &params).unwrap();
    for l in &plan.layers {
        assert!(l.spatial.is_none(), "{}: legacy must stay flat",
                l.name);
    }
    let mut eng = Engine::new(Arc::new(plan));
    let mut rng = Pcg64::new(9);
    let x: Vec<f32> = (0..man.input_shape.iter().product::<usize>())
        .map(|_| rng.normal())
        .collect();
    let y = eng.infer(&x).unwrap();
    assert_eq!(y.len(), man.num_classes);
    assert!(y.iter().all(|v| v.is_finite()));
}
