//! Multi-model registry/router battery: routing by model id,
//! byte-budget LRU eviction with transparent recompilation, plan-cache
//! counters, and isolation of per-model stats — plus the model
//! lifecycle paths: per-rung compile latches (a cold compile never
//! blocks warm traffic), versioned hot-swap with drain-then-retire,
//! pre-warming, and failed-compile counter hygiene. Synthetic plans
//! give deterministic integer outputs, so every served response is
//! checked bit-exactly against a direct `Engine` oracle — including
//! responses served *after* the model's compiled programs were
//! evicted.

#[path = "support/mod.rs"]
mod support;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use bayesian_bits::engine::registry::{closed_loop_router,
                                      ModelRegistry, Router};
use bayesian_bits::engine::serve::ServeConfig;
use bayesian_bits::engine::trace::TraceRecorder;
use bayesian_bits::engine::{lower, synthetic_plan, Engine, EnginePlan};

fn cfg() -> ServeConfig {
    ServeConfig {
        workers: 2,
        queue_cap: 32,
        max_batch: 4,
        deadline: Duration::from_micros(200),
        ..ServeConfig::default()
    }
}

fn plan_a() -> Arc<EnginePlan> {
    Arc::new(synthetic_plan("a", &[8, 16, 4], 4, 8, 0.2, 9).unwrap())
}

fn plan_b() -> Arc<EnginePlan> {
    Arc::new(synthetic_plan("b", &[6, 12, 3], 8, 8, 0.0, 4).unwrap())
}

fn input(dim: usize, salt: usize) -> Vec<f32> {
    (0..dim).map(|j| ((salt * dim + j) as f32 * 0.31).sin()).collect()
}

#[test]
fn routes_by_model_id_with_isolated_outputs_and_stats() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    assert_eq!(registry.model_ids(), vec!["a", "b"]);

    let mut ea = Engine::new(plan_a());
    let mut eb = Engine::new(plan_b());
    let router = Router::new(registry.clone());
    // interleave submissions; responses must come from the right model
    let mut pending = Vec::new();
    for i in 0..6 {
        let xa = input(8, i);
        let xb = input(6, i);
        let wa = ea.infer(&xa).unwrap();
        let wb = eb.infer(&xb).unwrap();
        pending.push((router.submit("a", xa).unwrap(), wa));
        pending.push((router.submit("b", xb).unwrap(), wb));
    }
    for (t, want) in pending {
        assert_eq!(t.wait().unwrap(), want);
    }
    // per-model stats are isolated; the aggregate sums them
    let sa = registry.stats("a").unwrap();
    let sb = registry.stats("b").unwrap();
    assert_eq!(sa.requests, 6);
    assert_eq!(sb.requests, 6);
    assert_eq!((sa.errors, sb.errors), (0, 0));
    let agg = registry.aggregate_stats();
    assert_eq!(agg.requests, 12);
    assert_eq!(agg.batches, sa.batches + sb.batches);
    registry.shutdown();
}

#[test]
fn zero_budget_lru_evicts_and_recompiles_transparently() {
    let registry = Arc::new(ModelRegistry::with_budget(0));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.resident_bytes(), 0);

    let mut ea = Engine::new(plan_a());
    let mut eb = Engine::new(plan_b());
    let oracle_a: Vec<Vec<f32>> =
        (0..3).map(|i| ea.infer(&input(8, i)).unwrap()).collect();
    let oracle_b = eb.infer(&input(6, 0)).unwrap();

    // 1) first submit to a: cold compile (miss), a resident
    assert_eq!(registry.submit("a", input(8, 0)).unwrap()
                   .wait().unwrap(), oracle_a[0]);
    assert_eq!(registry.is_resident("a"), Some(true));
    assert!(registry.resident_bytes() > 0);
    // 2) submit to b: miss, and the zero budget evicts a
    assert_eq!(registry.submit("b", input(6, 0)).unwrap()
                   .wait().unwrap(), oracle_b);
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.is_resident("b"), Some(true));
    // 3) back to a: recompile — the response is still bit-exact
    assert_eq!(registry.submit("a", input(8, 1)).unwrap()
                   .wait().unwrap(), oracle_a[1]);
    assert_eq!(registry.is_resident("b"), Some(false));
    // 4) a again while warm: a pure hit
    assert_eq!(registry.submit("a", input(8, 2)).unwrap()
                   .wait().unwrap(), oracle_a[2]);

    let c = registry.cache_stats();
    assert_eq!(c.hits, 1, "{c:?}");
    assert_eq!(c.misses, 3, "{c:?}");
    assert_eq!(c.recompiles, 1, "{c:?}");
    assert_eq!(c.evictions, 2, "{c:?}");
    // stats survived both evictions of a
    assert_eq!(registry.stats("a").unwrap().requests, 3);
    assert_eq!(registry.stats("b").unwrap().requests, 1);
    registry.shutdown();
    assert_eq!(registry.resident_bytes(), 0);
}

/// Eviction accounting must charge the *full* resident set of a
/// compiled model — both halves of the program pair (integer + f32
/// fallback), scaled by the per-worker scratch arenas — not just the
/// integer program. A budget sized to the int half alone must still
/// trigger eviction when the second model arrives.
#[test]
fn eviction_costing_counts_full_program_pair() {
    let c = cfg();
    let (ia, fa) = bayesian_bits::engine::compile_pair(&plan_a());
    let cost_a =
        (ia.arena_bytes() + fa.arena_bytes()) * c.max_batch * c.workers;
    let int_only = ia.arena_bytes() * c.max_batch * c.workers;
    assert!(cost_a > int_only, "f32 half must add to the cost");

    let registry = Arc::new(ModelRegistry::with_budget(cost_a));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    registry.submit("a", input(8, 0)).unwrap().wait().unwrap();
    // resident bytes reflect the pair cost exactly
    assert_eq!(registry.resident_bytes(), cost_a);
    // b does not fit next to a under a budget of exactly cost_a; if
    // the f32 half were uncounted, both would appear to fit
    registry.submit("b", input(6, 0)).unwrap().wait().unwrap();
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.is_resident("b"), Some(true));
    assert_eq!(registry.cache_stats().evictions, 1);
    registry.shutdown();
}

#[test]
fn explicit_evict_then_serve_again() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    let want = Engine::new(plan_a()).infer(&input(8, 5)).unwrap();
    assert_eq!(registry.submit("a", input(8, 5)).unwrap()
                   .wait().unwrap(), want);
    assert!(registry.evict("a"));
    assert_eq!(registry.is_resident("a"), Some(false));
    // already cold / unknown: no-ops
    assert!(!registry.evict("a"));
    assert!(!registry.evict("nope"));
    // next request recompiles
    assert_eq!(registry.submit("a", input(8, 5)).unwrap()
                   .wait().unwrap(), want);
    assert_eq!(registry.cache_stats().recompiles, 1);
}

#[test]
fn registration_and_routing_errors_are_typed_and_early() {
    let registry = ModelRegistry::new();
    registry.register("a", plan_a(), cfg()).unwrap();
    // re-registering an id is NOT an error any more — it installs a
    // new ladder version (hot-swap, pinned by the lifecycle tests
    // below)
    registry.register("a", plan_a(), cfg()).unwrap();
    assert_eq!(registry.cache_stats().swaps, 1);
    // empty id
    assert!(registry.register("", plan_b(), cfg()).is_err());
    // invalid config is rejected at registration, not first submit
    let bad = ServeConfig { max_batch: 0, ..cfg() };
    assert!(registry.register("c", plan_b(), bad).is_err());
    // unknown model names the registered set
    let err = registry.submit("zzz", vec![0.0; 8]).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unknown model") && msg.contains("\"a\""),
            "{msg}");
    // wrong input width is rejected before any compile
    let err = registry.submit("a", vec![0.0; 3]).unwrap_err();
    assert!(format!("{err}").contains("wants 8"), "{err}");
    assert_eq!(registry.is_resident("a"), Some(false));
    // shutdown closes registration too
    registry.shutdown();
    assert!(registry.register("d", plan_b(), cfg()).is_err());
    assert!(registry.submit("a", vec![0.0; 8]).is_err());
}

#[test]
fn closed_loop_router_drives_every_model_and_fills_throughput() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    let router = Router::new(registry.clone());
    let ids = vec!["a".to_string(), "b".to_string()];
    let (elapsed, per_model) =
        closed_loop_router(&router, &ids, 4, 30, 11).unwrap();
    assert!(elapsed > 0.0);
    assert_eq!(per_model.len(), 2);
    let total: u64 = per_model.iter().map(|(_, s)| s.requests).sum();
    assert_eq!(total, 4 * 30);
    for (id, st) in &per_model {
        assert!(st.requests > 0, "{id} starved");
        assert_eq!(st.errors, 0);
        assert!(st.throughput_rps > 0.0);
        assert_eq!(st.elapsed_s, elapsed);
    }
    // a cloned router routes to the same registry
    let r2 = router.clone();
    let want = Engine::new(plan_a()).infer(&input(8, 1)).unwrap();
    assert_eq!(r2.submit("a", input(8, 1)).unwrap().wait().unwrap(),
               want);
    registry.shutdown();
}

#[test]
fn stats_json_exposes_models_aggregate_and_cache() {
    let registry = Arc::new(ModelRegistry::with_budget(0));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    registry.submit("a", input(8, 0)).unwrap().wait().unwrap();
    registry.submit("b", input(6, 0)).unwrap().wait().unwrap();
    let j = registry.stats_json();
    let models = j.get("models").unwrap();
    assert_eq!(models.get("a").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 1);
    assert_eq!(models.get("b").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 1);
    assert_eq!(j.get("aggregate").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 2);
    let cache = j.get("cache").unwrap();
    assert_eq!(cache.get("misses").unwrap().as_usize().unwrap(), 2);
    assert_eq!(cache.get("evictions").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cache.get("budget_bytes").unwrap().as_usize().unwrap(),
               0);
    // only b is resident under the zero budget
    let resident = cache.get("resident_models").unwrap().as_arr()
        .unwrap();
    assert_eq!(resident.len(), 1);
    assert_eq!(resident[0].as_str().unwrap(), "b");
    // round-trips through the serializer
    let text = j.to_string();
    bayesian_bits::util::json::Json::parse(&text).unwrap();
}

// ------------------------------------------------------- lifecycle

/// A failed cold compile must move **no** cache counters and leave
/// the rung cold: a failed compile is not a miss, and the next
/// successful compile is a first compile, not a recompile. (The
/// counters used to be bumped before the compile could fail.)
#[test]
fn failed_compile_moves_no_counters() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    registry._set_compile_hook(Some(Arc::new(
        |_: &str, _: usize| Err("injected failure".to_string()))));
    let err = registry.submit("a", input(8, 0)).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("injected failure"), "{msg}");
    let c = registry.cache_stats();
    assert_eq!((c.hits, c.misses, c.recompiles, c.evictions,
                c.latch_waits),
               (0, 0, 0, 0, 0),
               "a failed compile must not move counters: {c:?}");
    assert_eq!(registry.is_resident("a"), Some(false));
    // with the failure cleared the same rung compiles as a plain
    // first miss — not a recompile
    registry._set_compile_hook(None);
    let want = Engine::new(plan_a()).infer(&input(8, 0)).unwrap();
    assert_eq!(registry.submit("a", input(8, 0)).unwrap()
                   .wait().unwrap(), want);
    let c = registry.cache_stats();
    assert_eq!((c.misses, c.recompiles), (1, 0), "{c:?}");
    registry.shutdown();
}

/// The tentpole regression pin: a cold rung compile runs off the
/// registry lock behind a per-rung latch, so warm models keep
/// serving while it is in flight, and a second submit to the cold
/// rung parks on the latch (counted) instead of compiling twice.
/// Before the latches this test deadlocked: the compile held the
/// registry mutex and every warm submit queued behind it.
#[test]
fn cold_compile_never_blocks_warm_traffic() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("w", plan_a(), cfg()).unwrap();
    registry.register("c", plan_b(), cfg()).unwrap();

    // gate: the cold model's compile blocks until released; the warm
    // model's compile passes straight through
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let entered = Arc::new(AtomicBool::new(false));
    let (g2, e2) = (gate.clone(), entered.clone());
    registry._set_compile_hook(Some(Arc::new(
        move |id: &str, _rung: usize| {
            if id == "c" {
                e2.store(true, Ordering::SeqCst);
                let (m, cv) = &*g2;
                let mut go = m.lock().unwrap();
                while !*go {
                    go = cv.wait(go).unwrap();
                }
            }
            Ok(())
        })));

    // warm up w (one miss)
    let mut ew = Engine::new(plan_a());
    let w0 = ew.infer(&input(8, 0)).unwrap();
    assert_eq!(registry.submit("w", input(8, 0)).unwrap()
                   .wait().unwrap(), w0);

    // start c's cold compile; it stalls inside the hook
    let mut eb = Engine::new(plan_b());
    let c0 = eb.infer(&input(6, 0)).unwrap();
    let c1 = eb.infer(&input(6, 1)).unwrap();
    let r1 = registry.clone();
    let t1 = thread::spawn(move || {
        r1.submit("c", input(6, 0)).unwrap().wait().unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while !entered.load(Ordering::SeqCst) {
        assert!(Instant::now() < deadline, "compile never started");
        thread::sleep(Duration::from_millis(1));
    }

    // warm traffic flows while the cold compile is pinned in flight
    for i in 1..=4 {
        let want = ew.infer(&input(8, i)).unwrap();
        assert_eq!(registry.submit("w", input(8, i)).unwrap()
                       .wait().unwrap(), want);
    }
    let c = registry.cache_stats();
    assert_eq!(c.hits, 4, "warm submits are pure hits: {c:?}");
    assert_eq!(c.misses, 1, "c's miss only counts on install: {c:?}");
    assert_eq!(c.latch_waits, 0, "{c:?}");

    // a second submit to the cold rung parks on the latch instead of
    // compiling a second copy
    let r2 = registry.clone();
    let t2 = thread::spawn(move || {
        r2.submit("c", input(6, 1)).unwrap().wait().unwrap()
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    while registry.cache_stats().latch_waits < 1 {
        assert!(Instant::now() < deadline,
                "second submit never parked on the latch");
        thread::sleep(Duration::from_millis(1));
    }

    // release the compile; both parked requests complete bit-exactly
    {
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
    assert_eq!(t1.join().unwrap(), c0);
    assert_eq!(t2.join().unwrap(), c1);

    let c = registry.cache_stats();
    assert_eq!(c.misses, 2, "one compile for two submits: {c:?}");
    assert_eq!(c.latch_waits, 1, "{c:?}");
    assert_eq!(c.hits, 4, "{c:?}");
    assert_eq!(c.recompiles, 0, "{c:?}");
    registry._set_compile_hook(None);
    registry.shutdown();
}

/// Re-registering a live id installs a new ladder version: new
/// submits route to the new plan, the superseded version retires
/// once idle (pools shut down, bytes reclaimed), and the `swaps` /
/// `drained` counters plus the per-model version fields record the
/// transition.
#[test]
fn hot_swap_routes_new_version_and_retires_old() {
    let v1 = plan_a();
    // same 8 -> 4 interface, different hidden layer: a genuinely
    // different function behind the same name
    let v2: Arc<EnginePlan> = Arc::new(
        synthetic_plan("a2", &[8, 24, 4], 4, 8, 0.0, 17).unwrap());
    let x = input(8, 3);
    let want_v1 = Engine::new(v1.clone()).infer(&x).unwrap();
    let want_v2 = Engine::new(v2.clone()).infer(&x).unwrap();
    assert_ne!(want_v1, want_v2,
               "swap must be observable through outputs");

    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", v1, cfg()).unwrap();
    assert_eq!(registry.submit("a", x.clone()).unwrap()
                   .wait().unwrap(), want_v1);
    assert_eq!(registry.versions("a"), Some((1, 1)));
    let warm_bytes = registry.resident_bytes();
    assert!(warm_bytes > 0);

    registry.register("a", v2, cfg()).unwrap();
    let c = registry.cache_stats();
    assert_eq!(c.swaps, 1, "{c:?}");
    // the old version was idle (its one request had completed), so
    // the registration sweep retired it on the spot: pools shut
    // down, bytes reclaimed
    assert_eq!(c.drained, 1, "{c:?}");
    assert_eq!(registry.resident_bytes(), 0);
    let (version, live) = registry.versions("a").unwrap();
    assert_eq!(version, 2);
    assert_eq!(live, 1);

    // new submits route to the new plan
    assert_eq!(registry.submit("a", x).unwrap().wait().unwrap(),
               want_v2);

    // the transition is visible in stats_json
    let j = registry.stats_json();
    let cache = j.get("cache").unwrap();
    assert_eq!(cache.get("swaps").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cache.get("drained").unwrap().as_usize().unwrap(), 1);
    let a = j.get("models").unwrap().get("a").unwrap();
    assert_eq!(a.get("version").unwrap().as_usize().unwrap(), 2);
    assert_eq!(a.get("versions_live").unwrap().as_usize().unwrap(), 1);
    registry.shutdown();
}

/// `prewarm` compiles every rung of the current ladder version up
/// front, so the first real submit is a cache hit instead of paying
/// a cold compile.
#[test]
fn prewarm_makes_first_submit_a_hit() {
    let lo = plan_a();
    let hi: Arc<EnginePlan> = Arc::new(
        synthetic_plan("a2", &[8, 24, 4], 4, 8, 0.0, 17).unwrap());
    let registry = Arc::new(ModelRegistry::new());
    registry
        .register_ladder_plans("lad",
                               vec![(0.3, lo.clone()), (0.6, hi)],
                               cfg())
        .unwrap();
    assert_eq!(registry.is_resident("lad"), Some(false));
    registry.prewarm("lad").unwrap();
    assert_eq!(registry.is_resident("lad"), Some(true));
    let c = registry.cache_stats();
    assert_eq!((c.misses, c.hits), (2, 0), "{c:?}");
    // the first submits to both rungs are now pure hits
    let want = Engine::new(lo).infer(&input(8, 0)).unwrap();
    assert_eq!(registry.submit_rung("lad", 0, input(8, 0)).unwrap()
                   .wait().unwrap(), want);
    registry.submit_rung("lad", 1, input(8, 1)).unwrap()
        .wait().unwrap();
    let c = registry.cache_stats();
    assert_eq!((c.misses, c.hits), (2, 2), "{c:?}");
    assert!(registry.prewarm("nope").is_err());
    registry.shutdown();
}

/// `set_trace` only affects pools spawned afterwards, so attaching a
/// recorder while pools are live would silently trace nothing — the
/// registry rejects it with a typed error instead. Evicting (forcing
/// the pools cold) releases the contract.
#[test]
fn set_trace_rejects_attach_while_pools_running() {
    let registry = ModelRegistry::new();
    registry.register("a", plan_a(), cfg()).unwrap();
    // no pools yet: attaching is fine
    registry.set_trace(Some(TraceRecorder::new())).unwrap();
    registry.submit("a", input(8, 0)).unwrap().wait().unwrap();
    // a pool is live now — it keeps the recorder it started with, so
    // swapping (or detaching) must be refused, not silently ignored
    let err = registry.set_trace(None).unwrap_err();
    assert!(format!("{err}").contains("already running"), "{err}");
    // forcing the model cold releases the contract
    assert!(registry.evict("a"));
    registry.set_trace(None).unwrap();
    registry.shutdown();
}

#[test]
fn preset_manifests_register_and_route_through_the_registry() {
    // the same builder the CLI uses for `--model NAME=preset:MODEL`
    let (man, params) = support::preset_manifest("lenet5", false);
    let registry = Arc::new(ModelRegistry::new());
    registry.register_manifest("lenet", &man, &params, cfg()).unwrap();
    let plan = registry.plan("lenet").unwrap();
    assert_eq!(plan.input_dim, 16 * 16);
    // oracle through a direct engine over the same lowering
    let lowered = Arc::new(lower(&man, &params).unwrap());
    let x = input(plan.input_dim, 3);
    let want = Engine::new(lowered).infer(&x).unwrap();
    let got = registry.submit("lenet", x).unwrap().wait().unwrap();
    assert_eq!(got, want);
    registry.shutdown();
}
