//! Multi-model registry/router battery: routing by model id,
//! byte-budget LRU eviction with transparent recompilation, plan-cache
//! counters, and isolation of per-model stats. Synthetic plans give
//! deterministic integer outputs, so every served response is checked
//! bit-exactly against a direct `Engine` oracle — including responses
//! served *after* the model's compiled programs were evicted.

#[path = "support/mod.rs"]
mod support;

use std::sync::Arc;
use std::time::Duration;

use bayesian_bits::engine::registry::{closed_loop_router,
                                      ModelRegistry, Router};
use bayesian_bits::engine::serve::ServeConfig;
use bayesian_bits::engine::{lower, synthetic_plan, Engine, EnginePlan};

fn cfg() -> ServeConfig {
    ServeConfig {
        workers: 2,
        queue_cap: 32,
        max_batch: 4,
        deadline: Duration::from_micros(200),
        ..ServeConfig::default()
    }
}

fn plan_a() -> Arc<EnginePlan> {
    Arc::new(synthetic_plan("a", &[8, 16, 4], 4, 8, 0.2, 9).unwrap())
}

fn plan_b() -> Arc<EnginePlan> {
    Arc::new(synthetic_plan("b", &[6, 12, 3], 8, 8, 0.0, 4).unwrap())
}

fn input(dim: usize, salt: usize) -> Vec<f32> {
    (0..dim).map(|j| ((salt * dim + j) as f32 * 0.31).sin()).collect()
}

#[test]
fn routes_by_model_id_with_isolated_outputs_and_stats() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    assert_eq!(registry.model_ids(), vec!["a", "b"]);

    let mut ea = Engine::new(plan_a());
    let mut eb = Engine::new(plan_b());
    let router = Router::new(registry.clone());
    // interleave submissions; responses must come from the right model
    let mut pending = Vec::new();
    for i in 0..6 {
        let xa = input(8, i);
        let xb = input(6, i);
        let wa = ea.infer(&xa).unwrap();
        let wb = eb.infer(&xb).unwrap();
        pending.push((router.submit("a", xa).unwrap(), wa));
        pending.push((router.submit("b", xb).unwrap(), wb));
    }
    for (t, want) in pending {
        assert_eq!(t.wait().unwrap(), want);
    }
    // per-model stats are isolated; the aggregate sums them
    let sa = registry.stats("a").unwrap();
    let sb = registry.stats("b").unwrap();
    assert_eq!(sa.requests, 6);
    assert_eq!(sb.requests, 6);
    assert_eq!((sa.errors, sb.errors), (0, 0));
    let agg = registry.aggregate_stats();
    assert_eq!(agg.requests, 12);
    assert_eq!(agg.batches, sa.batches + sb.batches);
    registry.shutdown();
}

#[test]
fn zero_budget_lru_evicts_and_recompiles_transparently() {
    let registry = Arc::new(ModelRegistry::with_budget(0));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.resident_bytes(), 0);

    let mut ea = Engine::new(plan_a());
    let mut eb = Engine::new(plan_b());
    let oracle_a: Vec<Vec<f32>> =
        (0..3).map(|i| ea.infer(&input(8, i)).unwrap()).collect();
    let oracle_b = eb.infer(&input(6, 0)).unwrap();

    // 1) first submit to a: cold compile (miss), a resident
    assert_eq!(registry.submit("a", input(8, 0)).unwrap()
                   .wait().unwrap(), oracle_a[0]);
    assert_eq!(registry.is_resident("a"), Some(true));
    assert!(registry.resident_bytes() > 0);
    // 2) submit to b: miss, and the zero budget evicts a
    assert_eq!(registry.submit("b", input(6, 0)).unwrap()
                   .wait().unwrap(), oracle_b);
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.is_resident("b"), Some(true));
    // 3) back to a: recompile — the response is still bit-exact
    assert_eq!(registry.submit("a", input(8, 1)).unwrap()
                   .wait().unwrap(), oracle_a[1]);
    assert_eq!(registry.is_resident("b"), Some(false));
    // 4) a again while warm: a pure hit
    assert_eq!(registry.submit("a", input(8, 2)).unwrap()
                   .wait().unwrap(), oracle_a[2]);

    let c = registry.cache_stats();
    assert_eq!(c.hits, 1, "{c:?}");
    assert_eq!(c.misses, 3, "{c:?}");
    assert_eq!(c.recompiles, 1, "{c:?}");
    assert_eq!(c.evictions, 2, "{c:?}");
    // stats survived both evictions of a
    assert_eq!(registry.stats("a").unwrap().requests, 3);
    assert_eq!(registry.stats("b").unwrap().requests, 1);
    registry.shutdown();
    assert_eq!(registry.resident_bytes(), 0);
}

/// Eviction accounting must charge the *full* resident set of a
/// compiled model — both halves of the program pair (integer + f32
/// fallback), scaled by the per-worker scratch arenas — not just the
/// integer program. A budget sized to the int half alone must still
/// trigger eviction when the second model arrives.
#[test]
fn eviction_costing_counts_full_program_pair() {
    let c = cfg();
    let (ia, fa) = bayesian_bits::engine::compile_pair(&plan_a());
    let cost_a =
        (ia.arena_bytes() + fa.arena_bytes()) * c.max_batch * c.workers;
    let int_only = ia.arena_bytes() * c.max_batch * c.workers;
    assert!(cost_a > int_only, "f32 half must add to the cost");

    let registry = Arc::new(ModelRegistry::with_budget(cost_a));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    registry.submit("a", input(8, 0)).unwrap().wait().unwrap();
    // resident bytes reflect the pair cost exactly
    assert_eq!(registry.resident_bytes(), cost_a);
    // b does not fit next to a under a budget of exactly cost_a; if
    // the f32 half were uncounted, both would appear to fit
    registry.submit("b", input(6, 0)).unwrap().wait().unwrap();
    assert_eq!(registry.is_resident("a"), Some(false));
    assert_eq!(registry.is_resident("b"), Some(true));
    assert_eq!(registry.cache_stats().evictions, 1);
    registry.shutdown();
}

#[test]
fn explicit_evict_then_serve_again() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    let want = Engine::new(plan_a()).infer(&input(8, 5)).unwrap();
    assert_eq!(registry.submit("a", input(8, 5)).unwrap()
                   .wait().unwrap(), want);
    assert!(registry.evict("a"));
    assert_eq!(registry.is_resident("a"), Some(false));
    // already cold / unknown: no-ops
    assert!(!registry.evict("a"));
    assert!(!registry.evict("nope"));
    // next request recompiles
    assert_eq!(registry.submit("a", input(8, 5)).unwrap()
                   .wait().unwrap(), want);
    assert_eq!(registry.cache_stats().recompiles, 1);
}

#[test]
fn registration_and_routing_errors_are_typed_and_early() {
    let registry = ModelRegistry::new();
    registry.register("a", plan_a(), cfg()).unwrap();
    // duplicate id
    let err = registry.register("a", plan_b(), cfg()).unwrap_err();
    assert!(format!("{err}").contains("already registered"), "{err}");
    // empty id
    assert!(registry.register("", plan_b(), cfg()).is_err());
    // invalid config is rejected at registration, not first submit
    let bad = ServeConfig { max_batch: 0, ..cfg() };
    assert!(registry.register("c", plan_b(), bad).is_err());
    // unknown model names the registered set
    let err = registry.submit("zzz", vec![0.0; 8]).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unknown model") && msg.contains("\"a\""),
            "{msg}");
    // wrong input width is rejected before any compile
    let err = registry.submit("a", vec![0.0; 3]).unwrap_err();
    assert!(format!("{err}").contains("wants 8"), "{err}");
    assert_eq!(registry.is_resident("a"), Some(false));
    // shutdown closes registration too
    registry.shutdown();
    assert!(registry.register("d", plan_b(), cfg()).is_err());
    assert!(registry.submit("a", vec![0.0; 8]).is_err());
}

#[test]
fn closed_loop_router_drives_every_model_and_fills_throughput() {
    let registry = Arc::new(ModelRegistry::new());
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    let router = Router::new(registry.clone());
    let ids = vec!["a".to_string(), "b".to_string()];
    let (elapsed, per_model) =
        closed_loop_router(&router, &ids, 4, 30, 11).unwrap();
    assert!(elapsed > 0.0);
    assert_eq!(per_model.len(), 2);
    let total: u64 = per_model.iter().map(|(_, s)| s.requests).sum();
    assert_eq!(total, 4 * 30);
    for (id, st) in &per_model {
        assert!(st.requests > 0, "{id} starved");
        assert_eq!(st.errors, 0);
        assert!(st.throughput_rps > 0.0);
        assert_eq!(st.elapsed_s, elapsed);
    }
    // a cloned router routes to the same registry
    let r2 = router.clone();
    let want = Engine::new(plan_a()).infer(&input(8, 1)).unwrap();
    assert_eq!(r2.submit("a", input(8, 1)).unwrap().wait().unwrap(),
               want);
    registry.shutdown();
}

#[test]
fn stats_json_exposes_models_aggregate_and_cache() {
    let registry = Arc::new(ModelRegistry::with_budget(0));
    registry.register("a", plan_a(), cfg()).unwrap();
    registry.register("b", plan_b(), cfg()).unwrap();
    registry.submit("a", input(8, 0)).unwrap().wait().unwrap();
    registry.submit("b", input(6, 0)).unwrap().wait().unwrap();
    let j = registry.stats_json();
    let models = j.get("models").unwrap();
    assert_eq!(models.get("a").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 1);
    assert_eq!(models.get("b").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 1);
    assert_eq!(j.get("aggregate").unwrap().get("requests").unwrap()
                   .as_usize().unwrap(), 2);
    let cache = j.get("cache").unwrap();
    assert_eq!(cache.get("misses").unwrap().as_usize().unwrap(), 2);
    assert_eq!(cache.get("evictions").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cache.get("budget_bytes").unwrap().as_usize().unwrap(),
               0);
    // only b is resident under the zero budget
    let resident = cache.get("resident_models").unwrap().as_arr()
        .unwrap();
    assert_eq!(resident.len(), 1);
    assert_eq!(resident[0].as_str().unwrap(), "b");
    // round-trips through the serializer
    let text = j.to_string();
    bayesian_bits::util::json::Json::parse(&text).unwrap();
}

#[test]
fn preset_manifests_register_and_route_through_the_registry() {
    // the same builder the CLI uses for `--model NAME=preset:MODEL`
    let (man, params) = support::preset_manifest("lenet5", false);
    let registry = Arc::new(ModelRegistry::new());
    registry.register_manifest("lenet", &man, &params, cfg()).unwrap();
    let plan = registry.plan("lenet").unwrap();
    assert_eq!(plan.input_dim, 16 * 16);
    // oracle through a direct engine over the same lowering
    let lowered = Arc::new(lower(&man, &params).unwrap());
    let x = input(plan.input_dim, 3);
    let want = Engine::new(lowered).infer(&x).unwrap();
    let got = registry.submit("lenet", x).unwrap().wait().unwrap();
    assert_eq!(got, want);
    registry.shutdown();
}
