//! Three-layer parity: python goldens vs Rust host oracle vs the
//! PJRT-executed Pallas kernel, plus manifest <-> descriptor
//! cross-checks. Requires `make artifacts`; each test self-skips when
//! the artifacts have not been built (CI runs host-only).

use std::path::{Path, PathBuf};

use bayesian_bits::models::{descriptor, Preset};
use bayesian_bits::quant::grid::{bb_quantize_host, QuantConfig};
use bayesian_bits::runtime::{Manifest, Runtime};
use bayesian_bits::util::json::Json;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// AOT artifacts are an optional build product; without them the
/// device-parity suite has nothing to check against.
fn artifacts_built() -> bool {
    let ok = artifacts_dir().join("lenet5_manifest.json").exists();
    if !ok {
        eprintln!("skipping: AOT artifacts not built \
                   (run `make artifacts`)");
    }
    ok
}

/// Device tests additionally need a real PJRT plugin — absent in
/// builds linked against the vendored `xla` stub.
fn runtime_ready() -> bool {
    if !artifacts_built() {
        return false;
    }
    match Runtime::cpu() {
        Ok(_) => true,
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e:#})");
            false
        }
    }
}

#[test]
fn goldens_match_host_and_device() {
    if !runtime_ready() {
        return;
    }
    let dir = artifacts_dir();
    let text =
        std::fs::read_to_string(dir.join("goldens.json")).unwrap();
    let g = Json::parse(&text).unwrap();
    let shape = g.get("shape").unwrap().usize_vec().unwrap();
    let levels: Vec<u32> = g.get("levels").unwrap().usize_vec().unwrap()
        .iter().map(|v| *v as u32).collect();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("quantizer_fwd.hlo.txt")).unwrap();
    let cfg = QuantConfig::new(true, &levels);
    for case in g.get("cases").unwrap().as_arr().unwrap() {
        let x = case.get("x").unwrap().f32_vec().unwrap();
        let beta = case.get("beta").unwrap().f32_vec().unwrap();
        let z2 = case.get("z2").unwrap().f32_vec().unwrap();
        let zh = case.get("zh").unwrap().f32_vec().unwrap();
        let want = case.get("out").unwrap().f32_vec().unwrap();
        let host =
            bb_quantize_host(&x, shape[0], beta[0], &z2, &zh, &cfg);
        let dev = rt
            .quantizer_fwd(&exe, &x, shape[0], &beta, &z2, &zh)
            .unwrap();
        for ((h, d), w) in host.iter().zip(&dev).zip(&want) {
            assert!((h - w).abs() < 1e-5,
                    "host {h} vs golden {w}");
            assert!((d - w).abs() < 1e-6,
                    "device {d} vs golden {w}");
        }
    }
}

#[test]
fn manifests_parse_and_validate_for_all_models() {
    if !artifacts_built() {
        return;
    }
    let dir = artifacts_dir();
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2",
                  "lenet5_dq", "vgg7_dq", "resnet18_dq"] {
        let man = Manifest::load(&dir, model).unwrap();
        assert!(man.n_params > 0);
        assert!(man.hlo_train.exists(), "{model} train HLO missing");
        assert!(man.hlo_eval.exists());
        let init = man.load_init().unwrap();
        assert_eq!(init.len(), man.n_params);
        assert!(init.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn manifest_layers_match_rust_descriptors() {
    if !artifacts_built() {
        return;
    }
    // The Rust-side model descriptors must agree with the python-built
    // manifests on MACs, channel counts and quantizer wiring.
    let dir = artifacts_dir();
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let man = Manifest::load(&dir, model).unwrap();
        let desc = descriptor(model, Preset::Small).unwrap();
        assert_eq!(man.layers.len(), desc.len(), "{model} layer count");
        for (a, b) in man.layers.iter().zip(&desc) {
            assert_eq!(a.name, b.name, "{model}");
            assert_eq!(a.macs, b.macs, "{model}/{}", a.name);
            assert_eq!(a.cin, b.cin, "{model}/{}", a.name);
            assert_eq!(a.cout, b.cout, "{model}/{}", a.name);
            assert_eq!(a.weight_q, b.weight_q);
            assert_eq!(a.act_q, b.act_q);
            // spatial metadata (ksize/stride/padding/groups/in map)
            // and recorded interstitial ops must agree so the
            // engine's spatial lowering matches the exporter's graph
            assert_eq!(a.conv, b.conv, "{model}/{}", a.name);
            assert_eq!(a.pre_ops, b.pre_ops, "{model}/{}", a.name);
        }
    }
}

#[test]
fn weight_quantizer_channels_match_layer_cout() {
    if !artifacts_built() {
        return;
    }
    let dir = artifacts_dir();
    let man = Manifest::load(&dir, "resnet18").unwrap();
    for l in &man.layers {
        let q = man.quantizer(&l.weight_q).unwrap();
        assert_eq!(q.channels, l.cout, "{}", l.name);
        assert!(q.signed);
        assert_eq!(q.kind, 'w');
    }
}

#[test]
fn lam_base_is_bop_proportional() {
    if !artifacts_built() {
        return;
    }
    let dir = artifacts_dir();
    let man = Manifest::load(&dir, "lenet5").unwrap();
    let max_macs =
        man.layers.iter().map(|l| l.macs).max().unwrap() as f64;
    for q in &man.quantizers {
        let scale = q.consumer_macs as f64 / max_macs;
        let ch_sum: f64 = man.lam_base
            [q.offset..q.offset + q.channels]
            .iter()
            .map(|v| *v as f64)
            .sum();
        assert!((ch_sum - 2.0 * scale).abs() < 1e-3,
                "{}: {ch_sum} vs {}", q.name, 2.0 * scale);
        for (i, b) in q.levels.iter().skip(1).enumerate() {
            let lam = man.lam_base[q.offset + q.channels + i] as f64;
            assert!((lam - *b as f64 * scale).abs() < 1e-3);
        }
    }
}

#[test]
fn eval_is_deterministic_and_gate_sensitive() {
    if !runtime_ready() {
        return;
    }
    let dir = artifacts_dir();
    let man = Manifest::load(&dir, "lenet5").unwrap();
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&man.hlo_eval).unwrap();
    let params = man.load_init().unwrap();
    let n_in = man.batch * man.input_shape.iter().product::<usize>();
    let x: Vec<f32> =
        (0..n_in).map(|i| ((i % 23) as f32 - 11.0) / 11.0).collect();
    let y: Vec<i32> = (0..man.batch).map(|i| (i % 10) as i32).collect();
    let open = vec![1.0f32; man.n_slots];
    let a = rt.eval_step(&exe, &man, &params, &open, &x, &y).unwrap();
    let b = rt.eval_step(&exe, &man, &params, &open, &x, &y).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.correct, b.correct);
    // closing every gate prunes the whole network -> different loss
    let closed = vec![0.0f32; man.n_slots];
    let c = rt.eval_step(&exe, &man, &params, &closed, &x, &y).unwrap();
    assert_ne!(a.loss, c.loss);
}
