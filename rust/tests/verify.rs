//! Mutation battery for the static plan verifier (`engine::verify`).
//!
//! Every clean program the pass pipeline can produce must verify with
//! zero findings (presets x backends x ladder rungs, both execution
//! paths); every hand-made corruption must be rejected with its
//! *specific* typed [`VerifyError`] — aliased arena slots, a swapped
//! panel, a 16-bit grid smuggled onto a low-bit node, a reference to
//! a retired node id, and so on. Programs are corrupted through the
//! `#[doc(hidden)]` mutation seams on [`Program`], after compiling
//! cleanly (debug builds auto-verify inside compile, so the
//! corruption must happen afterwards).

use std::sync::Arc;

use bayesian_bits::config::Mode;
use bayesian_bits::engine::graph::{Node, Program};
use bayesian_bits::engine::pack::{PackedMatrix, PanelMatrix};
use bayesian_bits::engine::verify::AccPath;
use bayesian_bits::engine::{self, kernels, synthetic_plan, verify_all,
                            Backend, VerifyError};
use bayesian_bits::quant::grid::CodeGrid;

#[path = "support/mod.rs"]
mod support;

/// Compile a synthetic GEMM chain on one forced backend and assert it
/// verifies clean — the starting point for every mutation below.
fn clean_program(dims: &[usize], w_bits: u32, a_bits: u32,
                 int_path: bool, backend: Backend) -> Program {
    let plan = Arc::new(
        synthetic_plan("verify", dims, w_bits, a_bits, 0.0, 7).unwrap());
    let prog =
        Program::try_compile_with_backend(plan, int_path, Some(backend))
            .unwrap();
    let errs = verify_all(&prog);
    assert!(errs.is_empty(),
            "clean {dims:?} w{w_bits}a{a_bits} {backend:?} plan must \
             verify: {errs:?}");
    prog
}

// ---------------------------------------------------------------- clean

/// Every preset x ladder rung x backend x path compiles to a program
/// with zero findings — the sweep `bbits plan --verify` runs in CI.
#[test]
fn clean_presets_verify_on_every_backend_and_rung() {
    for model in ["lenet5", "vgg7", "resnet18", "mobilenetv2"] {
        let (man, params) = support::preset_manifest(model, false);
        for t in [0.3, 0.5, 0.9] {
            let plan = Arc::new(
                engine::lower_with_mode_at(&man, &params,
                                           &Mode::BayesianBits, t)
                    .unwrap());
            for be in [Backend::Scalar, Backend::Simd, Backend::Blocked] {
                for int in [true, false] {
                    let prog = Program::try_compile_with_backend(
                        plan.clone(), int, Some(be))
                        .unwrap_or_else(|e| panic!(
                            "{model} t={t} {be:?} int={int}: {e}"));
                    let errs = verify_all(&prog);
                    assert!(errs.is_empty(),
                            "{model} t={t} {be:?} int={int}: {errs:?}");
                }
            }
        }
    }
}

/// Synthetic plans across widths / bit pairs / pruning also verify
/// clean on every backend.
#[test]
fn clean_synthetic_plans_verify() {
    for (dims, w, a, prune) in [
        (&[8usize, 16, 4][..], 4u32, 8u32, 0.2f64),
        (&[64, 32, 10][..], 8, 8, 0.0),
        (&[16, 24, 24, 6][..], 2, 4, 0.3),
        (&[40, 12][..], 8, 16, 0.0),
    ] {
        let plan = Arc::new(
            synthetic_plan("sweep", dims, w, a, prune, 11).unwrap());
        for be in [Backend::Scalar, Backend::Simd, Backend::Blocked] {
            for int in [true, false] {
                let prog = Program::try_compile_with_backend(
                    plan.clone(), int, Some(be)).unwrap();
                let errs = verify_all(&prog);
                assert!(errs.is_empty(),
                        "{dims:?} w{w}a{a} {be:?} int={int}: {errs:?}");
            }
        }
    }
}

// ---------------------------------------------------------------- arena

/// Aliasing two simultaneously-live f32 slots (epilogue src and dst)
/// is rejected as `ArenaAlias` naming both buffers.
#[test]
fn aliased_live_slots_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, false,
                                 Backend::Scalar);
    let (src, dst) = prog
        .nodes()
        .iter()
        .find_map(|n| match n {
            Node::Epilogue { src, dst, .. } => Some((*src, *dst)),
            _ => None,
        })
        .expect("f32 program ends in an epilogue");
    let off = prog.bufs()[src].offset.expect("src has a slot");
    prog.bufs_mut()[dst].offset = Some(off);
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e,
                VerifyError::ArenaAlias { a, b, .. }
                    if (*a == src && *b == dst)
                        || (*a == dst && *b == src))),
            "expected ArenaAlias({src}, {dst}), got {errs:?}");
}

/// A slot running past the end of its dtype arena is rejected as
/// `ArenaOutOfBounds`.
#[test]
fn out_of_bounds_slot_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, false,
                                 Backend::Scalar);
    let out = prog.output();
    prog.bufs_mut()[out].offset = Some(1 << 24);
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::ArenaOutOfBounds { buf, .. }
                    if *buf == out)),
            "expected ArenaOutOfBounds({out}), got {errs:?}");
}

// ------------------------------------------------------------- overflow

/// Replace the first quantizer's grid with the given one — the
/// "widen a node's codes without switching accumulators" mutation.
fn smuggle_grid(prog: &mut Program, grid: CodeGrid) {
    let q = prog
        .nodes_mut()
        .iter_mut()
        .find_map(|n| match n {
            Node::Quantize { grid, .. } => Some(grid),
            _ => None,
        })
        .expect("int program starts with a quantize");
    *q = grid;
}

/// A 16-bit unsigned grid smuggled onto a declared-8-bit node keeps
/// the low-bit dispatch (the declared width picks the path) but the
/// derived bound `max|w| * max|a| * block_len` now exceeds `i32`:
/// 127 * 65535 * 4096 > 2^31. The limit the verifier reports is the
/// accumulator type's own bound, not a hard-coded safety margin.
#[test]
fn widened_grid_overflows_low_bit_accumulator() {
    let mut prog = clean_program(&[4096, 16, 10], 8, 8, true,
                                 Backend::Scalar);
    smuggle_grid(&mut prog, CodeGrid::new(1.0, 16, false));
    let errs = verify_all(&prog);
    let err = errs
        .iter()
        .find(|e| matches!(e, VerifyError::AccumulatorOverflow { .. }))
        .unwrap_or_else(|| panic!(
            "expected AccumulatorOverflow, got {errs:?}"));
    let VerifyError::AccumulatorOverflow {
        path, max_w, max_a, block_len, bound, limit, ..
    } = err else { unreachable!() };
    assert_eq!(*path, AccPath::BlockedI32);
    assert_eq!(*max_w, 127);
    assert_eq!(*max_a, 65535);
    assert_eq!(*block_len, kernels::I32_BLOCK);
    assert_eq!(*limit, i32::MAX as i128, "limit is derived from the \
               accumulator type, not a fixed margin");
    assert!(*bound > *limit);
}

/// The same smuggled grid on a short reduction (64 columns) fits the
/// i32 accumulator but exceeds what the AVX2 `vpmaddwd` form can pack
/// into i16 lanes — a *different* typed error for the same mutation
/// class at a different shape.
#[test]
fn widened_grid_saturates_i16_pack() {
    let mut prog = clean_program(&[64, 16, 10], 8, 8, true,
                                 Backend::Scalar);
    smuggle_grid(&mut prog, CodeGrid::new(1.0, 16, false));
    let errs = verify_all(&prog);
    assert!(!errs.iter().any(|e| matches!(
                e, VerifyError::AccumulatorOverflow { .. })),
            "64-deep reduction fits i32: {errs:?}");
    assert!(errs.iter().any(|e| matches!(
                e,
                VerifyError::PackSaturation { max_code: 65535,
                                              limit: 32767, .. })),
            "expected PackSaturation(65535 > 32767), got {errs:?}");
}

/// The accumulator bound is derived from each backend's real block
/// length: the same smuggled grid overflows the scalar path's
/// `I32_BLOCK`-deep chunks but fits the blocked backend's `KC`-deep
/// panels (127 * 65535 * 256 < 2^31).
#[test]
fn block_length_is_backend_derived() {
    let mut scalar = clean_program(&[8192, 16, 10], 8, 8, true,
                                   Backend::Scalar);
    smuggle_grid(&mut scalar, CodeGrid::new(1.0, 16, false));
    let errs = verify_all(&scalar);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::AccumulatorOverflow { .. })),
            "scalar path accumulates 4096-deep: {errs:?}");

    let mut blocked = clean_program(&[8192, 16, 10], 8, 8, true,
                                    Backend::Blocked);
    smuggle_grid(&mut blocked, CodeGrid::new(1.0, 16, false));
    let errs = verify_all(&blocked);
    assert!(!errs.iter().any(|e| matches!(
                e, VerifyError::AccumulatorOverflow { .. })),
            "KC-deep panels keep the bound under i32: {errs:?}");
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::PackSaturation { .. })),
            "the i16 pack bound still rejects 16-bit codes: {errs:?}");
}

/// An integer kernel whose source has no propagated code range (its
/// producer is not a quantizer) is rejected as `MissingRange` — plus
/// the dtype mismatch the rewiring introduces.
#[test]
fn unquantized_kernel_source_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 8, 8, true,
                                 Backend::Scalar);
    let input = prog.input();
    for n in prog.nodes_mut().iter_mut() {
        if let Node::Gemm { src, .. } = n {
            *src = input;
            break;
        }
    }
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::MissingRange { buf, .. }
                    if *buf == input)),
            "expected MissingRange({input}), got {errs:?}");
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::EdgeDType { .. })),
            "expected EdgeDType alongside, got {errs:?}");
}

// ---------------------------------------------------------------- ids

/// Referencing a node id the pass pipeline retired (here: the ids
/// consumed by requant+quantize fusion) is rejected as
/// `RetiredNodeId`.
#[test]
fn retired_node_id_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Scalar);
    let retired = prog.retired_node_ids().to_vec();
    assert!(!retired.is_empty(),
            "fused plan must have retired ids");
    prog.node_ids_mut()[0] = retired[0];
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::RetiredNodeId { id, .. }
                    if *id == retired[0])),
            "expected RetiredNodeId({}), got {errs:?}", retired[0]);
}

/// An id past the pipeline's allocator high-water mark is rejected as
/// `UnknownNodeId`.
#[test]
fn unknown_node_id_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Scalar);
    let bogus = prog.id_bound() + 5;
    prog.node_ids_mut()[0] = bogus;
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::UnknownNodeId { id, .. }
                    if *id == bogus)),
            "expected UnknownNodeId({bogus}), got {errs:?}");
}

/// Two nodes sharing one id is rejected as `DuplicateNodeId`.
#[test]
fn duplicate_node_id_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Scalar);
    let first = prog.node_ids()[0];
    prog.node_ids_mut()[1] = first;
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::DuplicateNodeId { id, .. }
                    if *id == first)),
            "expected DuplicateNodeId({first}), got {errs:?}");
}

// ------------------------------------------------------------- dataflow

/// Rewiring a node to read a buffer defined later in the program is
/// rejected as `UseBeforeDef` by the recomputed liveness.
#[test]
fn use_before_def_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Scalar);
    let acc = prog
        .nodes()
        .iter()
        .find_map(|n| match n {
            Node::Gemm { dst, .. } => Some(*dst),
            _ => None,
        })
        .expect("int program has a gemm accumulator");
    match &mut prog.nodes_mut()[0] {
        Node::Quantize { src, .. } => *src = acc,
        other => panic!("node 0 should be quantize, got {other:?}"),
    }
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::UseBeforeDef { buf, .. }
                    if *buf == acc)),
            "expected UseBeforeDef({acc}), got {errs:?}");
}

// -------------------------------------------------------------- panels

/// Swapping a layer's panel for one packed from a different matrix
/// (wrong rows/cols, so wrong MR/KC partition) is rejected as
/// `PanelGeometry`.
#[test]
fn shrunken_panel_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Blocked);
    let codes: Vec<i64> = vec![1, -1, 2, 0, 1, -2];
    let small = PackedMatrix::pack(&codes, 2, 3, 4, true);
    prog.panels_mut()[0] =
        Some(Arc::new(PanelMatrix::from_packed(&small)));
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::PanelGeometry { layer: 0, .. })),
            "expected PanelGeometry(layer 0), got {errs:?}");
}

/// A blocked node whose layer has no compiled panels is rejected as
/// `MissingPanels`.
#[test]
fn missing_panels_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Blocked);
    prog.panels_mut()[0] = None;
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::MissingPanels { layer: 0, .. })),
            "expected MissingPanels(layer 0), got {errs:?}");
}

/// Truncating the panel table desynchronizes the parallel arrays —
/// the structural check reports `Malformed` (alone: nothing else can
/// be trusted once the arrays disagree).
#[test]
fn structural_corruption_reports_malformed() {
    let mut prog = clean_program(&[64, 32, 10], 4, 8, true,
                                 Backend::Blocked);
    prog.panels_mut().truncate(1);
    let errs = verify_all(&prog);
    assert_eq!(errs.len(), 1, "structural errors report alone: {errs:?}");
    assert!(matches!(errs[0], VerifyError::Malformed { .. }),
            "expected Malformed, got {errs:?}");
}

// ------------------------------------------------------- f32 ranges

/// Corrupting a requantize scale to a huge value makes the statically
/// bounded f32 edge exceed `f32::MAX` — rejected as
/// `F32RangeOverflow` on the requantizing node. Before this check
/// nothing bounded the folded `s_w * s_a` product: a corrupt scale
/// would serve `inf` logits without a single failed assertion.
#[test]
fn huge_requant_scale_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 8, 8, true,
                                 Backend::Scalar);
    let mut hit = None;
    for (i, n) in prog.nodes_mut().iter_mut().enumerate() {
        match n {
            Node::Requant { scale, .. }
            | Node::RequantQuantize { scale, .. } => {
                *scale = 1e300;
                hit = Some(i);
                break;
            }
            _ => {}
        }
    }
    let node = hit.expect("int program requantizes");
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::F32RangeOverflow { node: n, .. }
                    if *n == node)),
            "expected F32RangeOverflow(node {node}), got {errs:?}");
}

/// A non-finite requantize scale (NaN) trips the same finiteness
/// check, even though no ordered comparison against `f32::MAX` can
/// see NaN.
#[test]
fn nan_requant_scale_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 8, 8, true,
                                 Backend::Scalar);
    for n in prog.nodes_mut().iter_mut() {
        match n {
            Node::Requant { scale, .. }
            | Node::RequantQuantize { scale, .. } => {
                *scale = f64::NAN;
                break;
            }
            _ => {}
        }
    }
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::F32RangeOverflow { .. })),
            "expected F32RangeOverflow for NaN scale, got {errs:?}");
}

/// A corrupt dequantize step blows the bound on the simulated-quant
/// reference path the same way — the range propagation covers the
/// f32 edges on both execution paths.
#[test]
fn huge_dequantize_step_rejected() {
    let mut prog = clean_program(&[64, 32, 10], 8, 8, false,
                                 Backend::Scalar);
    let mut hit = None;
    for (i, n) in prog.nodes_mut().iter_mut().enumerate() {
        if let Node::Dequantize { step, .. } = n {
            *step = f32::MAX;
            hit = Some(i);
            break;
        }
    }
    let node = hit.expect("f32 program dequantizes its activations");
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::F32RangeOverflow { node: n, .. }
                    if *n == node)),
            "expected F32RangeOverflow(node {node}), got {errs:?}");
}

// -------------------------------------------------------- adapters

/// An `AdaptSpatial` node whose geometry disagrees with the plan
/// manifest is rejected as `AdapterGeometry` even when its flat
/// length is untouched. Swap a materialized max pool for a
/// product-preserving spatial adapter: every edge-shape check stays
/// blind (all flat widths still agree), only the comparison against
/// the layer's manifest pre-op and spatial input sees the wrong NHWC
/// interpretation.
#[test]
fn adapt_spatial_against_manifest_rejected() {
    let mut found = None;
    for model in ["vgg7", "lenet5", "resnet18"] {
        let (man, params) = support::preset_manifest(model, false);
        let plan = Arc::new(
            engine::lower_with_mode_at(&man, &params,
                                       &Mode::BayesianBits, 0.5)
                .unwrap());
        let prog = Program::try_compile_with_backend(
            plan, true, Some(Backend::Scalar)).unwrap();
        if prog.nodes().iter().any(|n| matches!(
                n, Node::MaxPool2 { .. })) {
            found = Some(prog);
            break;
        }
    }
    let mut prog = found.expect("a spatial preset materializes a \
                                 max pool");
    assert!(verify_all(&prog).is_empty());
    let (idx, repl) = prog
        .nodes()
        .iter()
        .enumerate()
        .find_map(|(i, n)| match n {
            Node::MaxPool2 { src, dst, h, w, c } => {
                Some((i, Node::AdaptSpatial {
                    src: *src,
                    dst: *dst,
                    from: (*h, *w, *c),
                    // same flat product as the pool's output, so no
                    // shape check can object
                    to: (h / 2, (w / 2) * c, 1),
                }))
            }
            _ => None,
        })
        .unwrap();
    prog.nodes_mut()[idx] = repl;
    let errs = verify_all(&prog);
    assert!(!errs.iter().any(|e| matches!(
                e, VerifyError::EdgeShape { .. })),
            "flat widths unchanged — shape checks stay blind: {errs:?}");
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::AdapterGeometry { node, .. }
                    if *node == idx)),
            "expected AdapterGeometry(node {idx}), got {errs:?}");
}

/// An `AdaptFeatures` bridge a buggy pass resized *consistently*
/// (node and its output buffer together) keeps its own edges
/// agreeing; the manifest comparison still pins the corruption to
/// the bridge, because the owning layer's input width is the one
/// width a rewrite pass cannot change.
#[test]
fn resized_adapt_features_rejected() {
    // the legacy flattened schema is what lowers with the bridge
    let (man, params) = support::preset_manifest("lenet5", true);
    let plan = Arc::new(
        engine::lower_with_mode_at(&man, &params,
                                   &Mode::BayesianBits, 0.5)
            .unwrap());
    let mut prog = Program::try_compile_with_backend(
        plan, true, Some(Backend::Scalar)).unwrap();
    assert!(verify_all(&prog).is_empty(),
            "legacy lenet5 verifies clean");
    let (idx, dst, want) = prog
        .nodes()
        .iter()
        .enumerate()
        .find_map(|(i, n)| match n {
            Node::AdaptFeatures { dst, want, .. } => {
                Some((i, *dst, *want))
            }
            _ => None,
        })
        .expect("legacy manifest lowers with an AdaptFeatures bridge");
    assert!(want > 1);
    match &mut prog.nodes_mut()[idx] {
        Node::AdaptFeatures { want, .. } => *want -= 1,
        _ => unreachable!(),
    }
    prog.bufs_mut()[dst].len = want - 1;
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e, VerifyError::AdapterGeometry { node, .. }
                    if *node == idx)),
            "expected AdapterGeometry(node {idx}), got {errs:?}");
}

// ------------------------------------------------------------- backends

/// Without a forced override, a SIMD assignment on a lane dimension
/// below the vector width is one the auto rule could not have
/// produced — rejected as `BackendRule`.
#[test]
fn backend_auto_rule_enforced() {
    // this test exercises the unforced path, so the env override must
    // not be in effect for this compile (every other test in this
    // binary forces its backend explicitly)
    std::env::remove_var("BBITS_BACKEND");
    let plan = Arc::new(
        synthetic_plan("small", &[4, 4, 10], 8, 8, 0.0, 5).unwrap());
    let mut prog =
        Program::try_compile_with_backend(plan, true, None).unwrap();
    assert!(verify_all(&prog).is_empty());
    for n in prog.nodes_mut().iter_mut() {
        if let Node::Gemm { backend, .. } = n {
            *backend = Backend::Simd;
            break;
        }
    }
    let errs = verify_all(&prog);
    assert!(errs.iter().any(|e| matches!(
                e,
                VerifyError::BackendRule { backend: Backend::Simd,
                                           lane_dim: 4, lanes: 8, .. })),
            "expected BackendRule(simd, lane 4 < 8), got {errs:?}");
}
