//! Offline stub of the `xla` crate (PJRT C API bindings).
//!
//! The native PJRT CPU plugin is a deployment-time dependency that the
//! offline build environment cannot provide, so this stub mirrors the
//! API surface `runtime::exec` compiles against. Every entry point
//! that would touch the device returns [`Error::Unavailable`];
//! [`PjRtClient::cpu`] is the single choke point, so callers see one
//! clear "PJRT unavailable" failure instead of a crash. Host-only
//! paths (the quantizer oracle, the integer engine, BOP accounting)
//! never reach this crate.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

/// Stub error: the native runtime is not present in this build.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "{what}: PJRT runtime not available in this build \
                 (offline xla stub; install the native plugin and \
                 point Cargo at the real xla crate)"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &'static str) -> Error {
    Error::Unavailable(what)
}

/// Element types the literal marshalling accepts.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}
impl NativeType for u32 {}

/// Host-side literal placeholder. Constructors succeed (they are pure
/// host bookkeeping); device transfers fail.
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// Always fails in the stub — the one place runtime construction
    /// is gated, so `Runtime::cpu()` reports a clean error.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("PJRT runtime not available"));
    }

    #[test]
    fn literal_constructors_are_host_only() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
