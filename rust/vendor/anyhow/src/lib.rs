//! Minimal in-tree re-implementation of the `anyhow` error-handling API.
//!
//! The build environment has no registry access, so this vendored crate
//! provides the exact subset the repository uses: [`Error`], the
//! [`Result`] alias, [`Context`] on `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics follow upstream
//! anyhow: `Error` deliberately does *not* implement
//! `std::error::Error` so the blanket `From<E: std::error::Error>`
//! conversion can exist, and `{:#}` formatting prints the full context
//! chain, outermost first.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a chain of context messages over an optional typed
/// root cause.
pub struct Error {
    /// Messages, outermost context first; the last entry is the root.
    chain: Vec<String>,
    /// The typed root cause, when built from a `std::error::Error`.
    root: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a plain message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], root: None }
    }

    fn from_std<E>(err: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { chain: vec![err.to_string()], root: Some(Box::new(err)) }
    }

    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The typed root cause, if this error wraps one.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.root.as_deref().map(|e| e as &(dyn StdError + 'static))
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, "outer: inner: root".
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        Error::from_std(err)
    }
}

// Same coherence trick as upstream anyhow: a private conversion trait
// with a blanket impl over std errors plus a concrete impl for `Error`
// (legal because `Error` is local and does not implement
// `std::error::Error`).
mod ext {
    use super::*;

    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E> IntoError for E
    where
        E: StdError + Send + Sync + 'static,
    {
        fn into_error(self) -> Error {
            Error::from_std(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to a fallible value (`Result` or `Option`).
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().wrap(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().wrap(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "gone");
        assert!(e.source().is_some());
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r
            .context("reading config")
            .map_err(|e| e.wrap("starting up"))
            .unwrap_err();
        assert_eq!(format!("{e}"), "starting up");
        assert_eq!(format!("{e:#}"), "starting up: reading config: gone");
    }

    #[test]
    fn context_on_option_and_anyhow_result() {
        let none: Option<u8> = None;
        assert_eq!(format!("{}", none.context("empty").unwrap_err()),
                   "empty");
        let r: Result<u8> = Err(Error::msg("root"));
        let e = r.with_context(|| format!("layer {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "layer 1: root");
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!("bad {} of {}", "kind", 7);
        assert_eq!(format!("{e}"), "bad kind of 7");
        fn f(flag: bool) -> Result<u8> {
            ensure!(flag, "flag was {flag}");
            bail!("always fails")
        }
        assert_eq!(format!("{}", f(false).unwrap_err()),
                   "flag was false");
        assert_eq!(format!("{}", f(true).unwrap_err()), "always fails");
    }
}
