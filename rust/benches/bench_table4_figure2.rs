//! Bench for Table 4 / Figure 2's workloads: ResNet18 and MobileNetV2
//! train-step latency across training modes (locks are runtime inputs
//! of a single executable, so mode must not change step cost — this
//! bench verifies that claim empirically).

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::gate_manager::GateManager;
use bayesian_bits::data::{generate, Batcher};
use bayesian_bits::runtime::{Manifest, Runtime, TrainState};
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("table4/figure2 — resnet18 / mobilenetv2 step latency by mode");
    let rt = Arc::new(Runtime::cpu().unwrap());
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for model in ["resnet18", "mobilenetv2"] {
        let man = Manifest::load(&dir, model).unwrap();
        let exe = rt.load(&man.hlo_train).unwrap();
        let mut state = TrainState::init(&man).unwrap();
        let ds = generate(&man.dataset, 1, false).unwrap();
        let mut batcher = Batcher::new(ds, man.batch, true, 1);
        let n_in =
            man.batch * man.input_shape.iter().product::<usize>();
        let mut x = vec![0.0f32; n_in];
        let mut y = vec![0i32; man.batch];
        let gm = GateManager::new(&man);
        let lam: Vec<f32> =
            man.lam_base.iter().map(|b| b * 0.05).collect();
        let bench = Bench::quick();
        for mode in [
            Mode::BayesianBits,
            Mode::QuantOnly,
            Mode::PruneOnly { w_bits: 4, a_bits: 8 },
            Mode::Fixed { w_bits: 8, a_bits: 8 },
        ] {
            let (mask, val) = gm.locks(&mode);
            let s = bench.run(
                &format!("{model}/train_step[{}]", mode.label()), || {
                    batcher.next_into(&mut x, &mut y);
                    rt.train_step(&exe, &man, &mut state, &x, &y, 7,
                                  (1e-3, 3e-2, 1e-3), &mask, &val,
                                  &lam, 0.0)
                        .unwrap();
                });
            println!("{}", s.line(Some((man.batch as f64, "img"))));
        }
    }
}
