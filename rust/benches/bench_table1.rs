//! Bench for Table 1's end-to-end inner loops: full train-step latency
//! and eval throughput for LeNet-5 (MNIST-like) and VGG-7 (CIFAR-like),
//! the two workloads of the paper's first experiment.

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::gate_manager::GateManager;
use bayesian_bits::data::{generate, Batcher};
use bayesian_bits::runtime::{Manifest, Runtime, TrainState};
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("table1 — lenet5 / vgg7 end-to-end step latency");
    let rt = Arc::new(Runtime::cpu().unwrap());
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    for model in ["lenet5", "vgg7"] {
        bench_model(&rt, &dir, model);
    }
}

fn bench_model(rt: &Arc<Runtime>, dir: &Path, model: &str) {
    let man = Manifest::load(dir, model).unwrap();
    let train_exe = rt.load(&man.hlo_train).unwrap();
    let eval_exe = rt.load(&man.hlo_eval).unwrap();
    let mut state = TrainState::init(&man).unwrap();
    let ds = generate(&man.dataset, 1, false).unwrap();
    let mut batcher = Batcher::new(ds, man.batch, false, 1);
    let n_in = man.batch * man.input_shape.iter().product::<usize>();
    let mut x = vec![0.0f32; n_in];
    let mut y = vec![0i32; man.batch];
    let g = man.n_slots;
    let gm = GateManager::new(&man);
    let (mask, val) = gm.locks(&Mode::BayesianBits);
    let lam: Vec<f32> =
        man.lam_base.iter().map(|b| b * 0.01).collect();

    let b = Bench::default();
    let s = b.run(&format!("{model}/train_step(batch={})", man.batch),
                  || {
        batcher.next_into(&mut x, &mut y);
        rt.train_step(&train_exe, &man, &mut state, &x, &y, 7,
                      (1e-3, 3e-2, 1e-3), &mask, &val, &lam, 0.0)
            .unwrap();
    });
    println!("{}", s.line(Some((man.batch as f64, "img"))));

    let gates = vec![1.0f32; g];
    let s = b.run(&format!("{model}/eval_step(batch={})", man.batch),
                  || {
        rt.eval_step(&eval_exe, &man, &state.params, &gates, &x, &y)
            .unwrap();
    });
    println!("{}", s.line(Some((man.batch as f64, "img"))));
}
