//! Runtime microbenches: isolate the PJRT execute + literal marshalling
//! overhead from the model compute, to show where L3 time goes.

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::runtime::{Manifest, Runtime, TrainState};
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("runtime — PJRT execute + marshalling overhead");
    let rt = Arc::new(Runtime::cpu().unwrap());
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    // tiny executable: quantizer forward (8x16) isolates dispatch cost
    let qexe = rt.load(&dir.join("quantizer_fwd.hlo.txt")).unwrap();
    let x = vec![0.5f32; 8 * 16];
    let b = Bench::default();
    let s = b.run("quantizer_fwd(8x16) dispatch", || {
        rt.quantizer_fwd(&qexe, &x, 8, &[2.0], &[1.0; 8], &[1.0; 4])
            .unwrap();
    });
    println!("{}", s.line(None));

    // literal construction costs at train-state sizes
    let man = Manifest::load(&dir, "resnet18").unwrap();
    let state = TrainState::init(&man).unwrap();
    let s = b.run(&format!("Literal::vec1({} f32)", man.n_params), || {
        let lit = xla::Literal::vec1(&state.params);
        std::hint::black_box(lit);
    });
    println!("{}", s.line(Some((man.n_params as f64 * 4.0 / 1e6,
                                "MB"))));

    let lit = xla::Literal::vec1(&state.params);
    let s = b.run(&format!("Literal::to_vec({} f32)", man.n_params),
                  || {
        let v = lit.to_vec::<f32>().unwrap();
        std::hint::black_box(v);
    });
    println!("{}", s.line(Some((man.n_params as f64 * 4.0 / 1e6,
                                "MB"))));

    // executable cache hit path
    let s = b.run("Runtime::load (cache hit)", || {
        let e = rt.load(&dir.join("quantizer_fwd.hlo.txt")).unwrap();
        std::hint::black_box(e);
    });
    println!("{}", s.line(None));
}
