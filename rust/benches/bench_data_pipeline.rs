//! Data-pipeline benches: generator throughput and the per-step batch
//! assembly cost (which sits on the training hot loop).

use bayesian_bits::data::synth::{generate, DatasetSpec};
use bayesian_bits::data::Batcher;
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("data pipeline — generation + batch assembly");
    let b = Bench::quick();

    for (name, c) in [("mnist_like", 1), ("cifar_like", 3),
                      ("imagenet_like", 3)] {
        let spec = DatasetSpec {
            name: name.into(),
            input: (24, 24, c),
            classes: 10,
            train: 1024,
            test: 0,
        };
        let s = b.run(&format!("generate({name}, 1024x24x24x{c})"), || {
            let ds = generate(&spec, 1, false).unwrap();
            std::hint::black_box(ds);
        });
        println!("{}", s.line(Some((1024.0, "img"))));
    }

    let spec = DatasetSpec {
        name: "cifar_like".into(),
        input: (24, 24, 3),
        classes: 10,
        train: 4096,
        test: 0,
    };
    let ds = generate(&spec, 1, false).unwrap();
    let n_px = ds.image_size();
    for augment in [false, true] {
        let mut batcher = Batcher::new(ds.clone(), 32, augment, 1);
        let mut x = vec![0.0f32; 32 * n_px];
        let mut y = vec![0i32; 32];
        let bb = Bench::default();
        let s = bb.run(&format!("next_into(batch=32, augment={augment})"),
                       || {
            batcher.next_into(&mut x, &mut y);
        });
        println!("{}", s.line(Some((32.0, "img"))));
    }
}
