//! Host quantizer-math benches: the Rust-side mirror used for gate
//! thresholding, BOP accounting and parity tests. These run on every
//! eval boundary, so they should be negligible next to device steps.

use std::collections::BTreeMap;

use bayesian_bits::bops::{BopCounter, QuantState};
use bayesian_bits::models::{descriptor, Preset};
use bayesian_bits::quant::gates::{test_time_gate, GateView};
use bayesian_bits::quant::grid::{bb_quantize_host, QuantConfig};
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("quant host — oracle quantizer, thresholding, BOP accounting");
    let b = Bench::default();

    let cfg = QuantConfig::new(true, &[2, 4, 8, 16, 32]);
    let n = 64 * 1024;
    let x: Vec<f32> =
        (0..n).map(|i| ((i % 997) as f32 - 498.0) / 200.0).collect();
    let z2 = vec![1.0f32; 64];
    let zh = [1.0f32, 1.0, 1.0, 1.0];
    let s = b.run("bb_quantize_host(64x1024, 5 levels)", || {
        let out = bb_quantize_host(&x, 64, 2.0, &z2, &zh, &cfg);
        std::hint::black_box(out);
    });
    println!("{}", s.line(Some((n as f64 / 1e6, "Melem"))));

    let phis: Vec<f64> =
        (0..10_000).map(|i| (i as f64 - 5000.0) / 500.0).collect();
    let s = b.run("test_time_gate x 10k (Eq. 22)", || {
        let open = phis.iter().filter(|p| test_time_gate(**p)).count();
        std::hint::black_box(open);
    });
    println!("{}", s.line(Some((10_000.0, "gate"))));

    let view = GateView { channels: 512, levels: vec![2, 4, 8, 16, 32] };
    let probs = vec![0.97f32; view.n_slots()];
    let s = b.run("expected_bits(512-channel quantizer)", || {
        std::hint::black_box(view.expected_bits(&probs));
    });
    println!("{}", s.line(None));

    // BOP accounting at paper-scale ResNet18
    let layers = descriptor("resnet18", Preset::Paper).unwrap();
    let counter = BopCounter::new(layers.clone());
    let mut states: BTreeMap<String, QuantState> =
        counter.fixed_states(8, 8);
    for (i, l) in layers.iter().enumerate() {
        states.insert(l.weight_q.clone(), QuantState {
            bits: [2u32, 4, 8, 16][i % 4],
            keep_ratio: 0.9,
        });
    }
    let s = b.run("BopCounter::bops(paper resnet18, mixed)", || {
        std::hint::black_box(counter.bops(&states));
    });
    println!("{}", s.line(None));
}
