//! Integer engine throughput: images/sec per bit-width config and
//! batch size, integer path vs the f32 simulated-quant fallback.
//!
//! The packed low-bit path wins on memory traffic (a 2-bit layer
//! streams 16x fewer weight bytes than f32) and the win grows with
//! batch size because each packed row is decoded once per batch.
//! Emits `BENCH_engine.json` in the working directory — the
//! machine-readable artifact perf tracking reads. The sweep itself is
//! `engine::throughput_sweep`, shared with `bbits engine-bench`.

use std::path::Path;

use bayesian_bits::engine::throughput_sweep;
use bayesian_bits::util::bench::{header, save_json, Bench};

fn main() {
    // Large enough that f32 weights (ROWS*COLS*4 = 16 MiB) fall out
    // of cache while 2-bit packed rows (1 MiB) do not.
    const ROWS: usize = 2048;
    const COLS: usize = 2048;
    header(&format!(
        "integer engine — {ROWS}x{COLS} layer, int vs f32 fallback"
    ));
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };

    let records =
        throughput_sweep(ROWS, COLS, &[1, 16], &[2, 4, 8, 16], &b)
            .unwrap();
    for rec in &records {
        println!("{}", rec.line());
    }
    save_json(Path::new("BENCH_engine.json"),
              "engine images/sec vs batch size per bit-width config",
              records.iter().map(|r| r.to_json()).collect())
        .unwrap();
    println!("wrote BENCH_engine.json");
}
