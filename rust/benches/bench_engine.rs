//! Integer engine throughput: images/sec per bit-width config and
//! batch size — scalar vs SIMD integer kernel backends vs the f32
//! simulated-quant fallback.
//!
//! The packed low-bit path wins on memory traffic (a 2-bit layer
//! streams 16x fewer weight bytes than f32) and the win grows with
//! batch size because each packed row is decoded once per batch; the
//! SIMD backend then widens the compute side (8 i32 multiply-adds per
//! step, AVX2/NEON where the CPU has them) with bit-identical
//! results. Emits `BENCH_engine.json` in the working directory — the
//! machine-readable artifact perf tracking reads; every record
//! carries a `backend` column plus a `nodes` per-(op, backend,
//! bit-width) breakdown column measured by a short profiled pass run
//! after the timed loop (the timed loop itself stays uninstrumented).
//! The sweep itself is `engine::throughput_sweep`, shared with
//! `bbits engine-bench`.

use std::path::Path;

use bayesian_bits::engine::{throughput_sweep, BENCH_ENGINE_TITLE};
use bayesian_bits::util::bench::{header, save_json, Bench};

fn main() {
    // Large enough that f32 weights (ROWS*COLS*4 = 16 MiB) fall out
    // of cache while 2-bit packed rows (1 MiB) do not.
    const ROWS: usize = 2048;
    const COLS: usize = 2048;
    header(&format!(
        "integer engine — {ROWS}x{COLS} layer, scalar/simd int vs f32"
    ));
    let quick = std::env::args().any(|a| a == "--quick");
    let b = if quick { Bench::quick() } else { Bench::default() };

    // forced=None sweeps both integer backends plus the f32 reference
    let records =
        throughput_sweep(ROWS, COLS, &[1, 16], &[2, 4, 8, 16], None,
                         &b)
            .unwrap();
    for rec in &records {
        println!("{}", rec.line());
    }
    save_json(Path::new("BENCH_engine.json"), BENCH_ENGINE_TITLE,
              records.iter().map(|r| r.to_json()).collect())
        .unwrap();
    println!("wrote BENCH_engine.json");
}
