//! Bench for Table 5 / Figure 3's post-training loop: the PTQ step
//! (weights frozen, gates+scales learning) and the sensitivity
//! baseline's unit of work (one full-testset evaluation).

use std::path::Path;
use std::sync::Arc;

use bayesian_bits::config::Mode;
use bayesian_bits::coordinator::gate_manager::GateManager;
use bayesian_bits::data::{generate, Batcher};
use bayesian_bits::runtime::{Manifest, Runtime, TrainState};
use bayesian_bits::util::bench::{header, Bench};

fn main() {
    header("table5/figure3 — post-training step + sensitivity eval unit");
    let rt = Arc::new(Runtime::cpu().unwrap());
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let man = Manifest::load(&dir, "resnet18").unwrap();
    let train_exe = rt.load(&man.hlo_train).unwrap();
    let eval_exe = rt.load(&man.hlo_eval).unwrap();
    let mut state = TrainState::init(&man).unwrap();
    let ds = generate(&man.dataset, 1, false).unwrap();
    let test = generate(&man.dataset, 1, true).unwrap();
    let mut batcher = Batcher::new(ds, man.batch, false, 1);
    let n_in = man.batch * man.input_shape.iter().product::<usize>();
    let mut x = vec![0.0f32; n_in];
    let mut y = vec![0i32; man.batch];
    let gm = GateManager::new(&man);
    let (mask, val) = gm.locks(&Mode::BayesianBits);
    let lam: Vec<f32> = man.lam_base.iter().map(|b| b * 0.005).collect();

    let bench = Bench::quick();
    // PTQ step: lr_w = 0 (frozen weights), gates + scales learn.
    let s = bench.run("resnet18/ptq_step(lr_w=0)", || {
        batcher.next_into(&mut x, &mut y);
        rt.train_step(&train_exe, &man, &mut state, &x, &y, 7,
                      (0.0, 3e-2, 1e-3), &mask, &val, &lam, 0.0)
            .unwrap();
    });
    println!("{}", s.line(Some((man.batch as f64, "img"))));

    // sensitivity baseline unit: one full test-set evaluation
    let gates = vec![1.0f32; man.n_slots];
    let s = bench.run("resnet18/full_testset_eval", || {
        Batcher::for_eval(&test, man.batch, |bx, by, _| {
            rt.eval_step(&eval_exe, &man, &state.params, &gates, bx, by)
                .unwrap();
        });
    });
    println!("{}", s.line(Some((test.len() as f64, "img"))));
}
